//! Model metadata: the Rust mirror of python/compile/config.py plus the
//! artifacts/manifest.json loader. Everything the engine needs to know
//! about shapes, buckets and arg contracts comes from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ffn: usize,
    pub kv_dim: usize,
    pub params: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct HeadVariant {
    pub name: String,
    /// "medusa" | "hydra" | "eagle"
    pub kind: String,
    pub mlp_layers: usize,
    pub prefix_attn: bool,
    pub objective: String,
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// "dyn" | "base" | "head"
    pub kind: String,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<(Vec<usize>, String)>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub vocab: usize,
    pub seq_max: usize,
    pub accept_max: usize,
    pub num_heads: usize,
    pub tree_buckets: Vec<usize>,
    pub batch_buckets: BTreeMap<String, Vec<usize>>,
    pub hydra_m_buckets: BTreeMap<String, Vec<usize>>,
    pub eagle_n_buckets: Vec<usize>,
    pub sizes: BTreeMap<String, ModelDims>,
    pub head_variants: BTreeMap<String, Vec<HeadVariant>>,
    pub weight_files: BTreeMap<String, String>,
    pub executables: BTreeMap<String, ExeSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = Json::parse_file(&dir.join("manifest.json"))?;
        let sizes = v
            .req("sizes")
            .as_obj()
            .context("sizes")?
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    ModelDims {
                        d_model: s.req("d_model").as_usize().unwrap(),
                        n_layers: s.req("n_layers").as_usize().unwrap(),
                        n_heads: s.req("n_heads").as_usize().unwrap(),
                        n_kv_heads: s.req("n_kv_heads").as_usize().unwrap(),
                        d_ffn: s.req("d_ffn").as_usize().unwrap(),
                        kv_dim: s.req("kv_dim").as_usize().unwrap(),
                        params: s.req("params").as_usize().unwrap(),
                    },
                )
            })
            .collect();
        let head_variants = v
            .req("head_variants")
            .as_obj()
            .context("head_variants")?
            .iter()
            .map(|(k, arr)| {
                let vs = arr
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|h| HeadVariant {
                        name: h.req("name").as_str().unwrap().to_string(),
                        kind: h.req("kind").as_str().unwrap().to_string(),
                        mlp_layers: h.req("mlp_layers").as_usize().unwrap(),
                        prefix_attn: h.req("prefix_attn").as_bool().unwrap(),
                        objective: h.req("objective").as_str().unwrap().to_string(),
                    })
                    .collect();
                (k.clone(), vs)
            })
            .collect();
        let executables = v
            .req("executables")
            .as_obj()
            .context("executables")?
            .iter()
            .map(|(k, e)| {
                let args = e
                    .req("args")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|a| ArgSpec {
                        kind: a.req("kind").as_str().unwrap().to_string(),
                        name: a.req("name").as_str().unwrap().to_string(),
                        shape: a.get("shape").map(|s| s.usize_arr()).unwrap_or_default(),
                        dtype: a
                            .get("dtype")
                            .and_then(|d| d.as_str())
                            .unwrap_or("f32")
                            .to_string(),
                    })
                    .collect();
                let outputs = e
                    .req("outputs")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|o| {
                        (o.req("shape").usize_arr(),
                         o.req("dtype").as_str().unwrap().to_string())
                    })
                    .collect();
                (
                    k.clone(),
                    ExeSpec { file: e.req("file").as_str().unwrap().to_string(), args, outputs },
                )
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: v.req("vocab").as_usize().context("vocab")?,
            seq_max: v.req("seq_max").as_usize().context("seq_max")?,
            accept_max: v.req("accept_max").as_usize().context("accept_max")?,
            num_heads: v.req("num_heads").as_usize().context("num_heads")?,
            tree_buckets: v.req("tree_buckets").usize_arr(),
            batch_buckets: v
                .req("batch_buckets")
                .as_obj()
                .context("batch_buckets")?
                .iter()
                .map(|(k, a)| (k.clone(), a.usize_arr()))
                .collect(),
            hydra_m_buckets: v
                .req("hydra_m_buckets")
                .as_obj()
                .context("hydra_m_buckets")?
                .iter()
                .map(|(k, a)| (k.clone(), a.usize_arr()))
                .collect(),
            eagle_n_buckets: v.req("eagle_n_buckets").usize_arr(),
            sizes,
            head_variants,
            weight_files: v
                .req("weight_files")
                .as_obj()
                .context("weight_files")?
                .iter()
                .map(|(k, f)| (k.clone(), f.as_str().unwrap().to_string()))
                .collect(),
            executables,
        })
    }

    pub fn dims(&self, size: &str) -> Result<&ModelDims> {
        self.sizes.get(size).with_context(|| format!("unknown size `{size}`"))
    }

    pub fn variant(&self, size: &str, name: &str) -> Result<&HeadVariant> {
        self.head_variants
            .get(size)
            .and_then(|vs| vs.iter().find(|v| v.name == name))
            .with_context(|| format!("no head variant `{name}` for size `{size}`"))
    }

    /// Smallest bucket >= n, or an error if none fits.
    pub fn bucket(buckets: &[usize], n: usize) -> Result<usize> {
        buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .with_context(|| format!("no bucket >= {n} in {buckets:?}"))
    }

    pub fn tree_bucket(&self, n: usize) -> Result<usize> {
        Self::bucket(&self.tree_buckets, n)
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables.get(name).with_context(|| format!("no executable `{name}`"))
    }

    pub fn has_exe(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = vec![1, 4, 8, 16, 32, 64];
        assert_eq!(Manifest::bucket(&buckets, 1).unwrap(), 1);
        assert_eq!(Manifest::bucket(&buckets, 2).unwrap(), 4);
        assert_eq!(Manifest::bucket(&buckets, 16).unwrap(), 16);
        assert_eq!(Manifest::bucket(&buckets, 33).unwrap(), 64);
        assert!(Manifest::bucket(&buckets, 65).is_err());
    }
}
