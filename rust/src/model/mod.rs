//! Model metadata: the Rust mirror of python/compile/config.py plus the
//! artifacts/manifest.json loader. Everything the engine needs to know
//! about shapes, buckets and arg contracts comes from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Transformer dimensions of one model size.
#[derive(Debug, Clone)]
pub struct ModelDims {
    /// Hidden width.
    pub d_model: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// KV heads (GQA).
    pub n_kv_heads: usize,
    /// Feed-forward width.
    pub d_ffn: usize,
    /// KV row width per layer (n_kv_heads × head_dim).
    pub kv_dim: usize,
    /// Total parameter count.
    pub params: usize,
}

/// One trained draft-head variant of a model size.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadVariant {
    /// Variant name as addressed by the CLI/benches.
    pub name: String,
    /// "medusa" | "hydra" | "eagle"
    pub kind: String,
    /// Hydra head MLP depth.
    pub mlp_layers: usize,
    /// Whether the variant uses prefix attention (Hydra++).
    pub prefix_attn: bool,
    /// Training objective label ("ntp", "teacher", ...).
    pub objective: String,
}

/// One argument slot of an AOT executable.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// "dyn" | "base" | "head"
    pub kind: String,
    /// Argument name (weight tensors are resolved by it).
    pub name: String,
    /// Expected shape (dyn args only).
    pub shape: Vec<usize>,
    /// Expected dtype ("f32" | "i32").
    pub dtype: String,
}

/// Executable artifact descriptor: file plus its I/O contract.
#[derive(Debug, Clone)]
pub struct ExeSpec {
    /// HLO-text file path relative to the artifacts dir.
    pub file: String,
    /// Argument slots in call order.
    pub args: Vec<ArgSpec>,
    /// Output (shape, dtype) pairs in tuple order.
    pub outputs: Vec<(Vec<usize>, String)>,
}

/// The artifacts/manifest.json contents: everything the engine needs to
/// know about shapes, buckets and executable contracts.
#[derive(Debug)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Vocabulary size.
    pub vocab: usize,
    /// Per-slot KV capacity (max sequence length).
    pub seq_max: usize,
    /// Max accepted tokens per step the commit path supports.
    pub accept_max: usize,
    /// Number of draft heads K.
    pub num_heads: usize,
    /// AOT tree-node buckets for verify/commit.
    pub tree_buckets: Vec<usize>,
    /// AOT batch buckets per model size.
    pub batch_buckets: BTreeMap<String, Vec<usize>>,
    /// Hydra draft-call row buckets per model size.
    pub hydra_m_buckets: BTreeMap<String, Vec<usize>>,
    /// EAGLE per-depth node buckets.
    pub eagle_n_buckets: Vec<usize>,
    /// Model dimensions per size key.
    pub sizes: BTreeMap<String, ModelDims>,
    /// Trained head variants per size key.
    pub head_variants: BTreeMap<String, Vec<HeadVariant>>,
    /// Weight-set name → HTB1 file.
    pub weight_files: BTreeMap<String, String>,
    /// Executable name → artifact descriptor.
    pub executables: BTreeMap<String, ExeSpec>,
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = Json::parse_file(&dir.join("manifest.json"))?;
        let sizes = v
            .req("sizes")
            .as_obj()
            .context("sizes")?
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    ModelDims {
                        d_model: s.req("d_model").as_usize().unwrap(),
                        n_layers: s.req("n_layers").as_usize().unwrap(),
                        n_heads: s.req("n_heads").as_usize().unwrap(),
                        n_kv_heads: s.req("n_kv_heads").as_usize().unwrap(),
                        d_ffn: s.req("d_ffn").as_usize().unwrap(),
                        kv_dim: s.req("kv_dim").as_usize().unwrap(),
                        params: s.req("params").as_usize().unwrap(),
                    },
                )
            })
            .collect();
        let head_variants = v
            .req("head_variants")
            .as_obj()
            .context("head_variants")?
            .iter()
            .map(|(k, arr)| {
                let vs = arr
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|h| HeadVariant {
                        name: h.req("name").as_str().unwrap().to_string(),
                        kind: h.req("kind").as_str().unwrap().to_string(),
                        mlp_layers: h.req("mlp_layers").as_usize().unwrap(),
                        prefix_attn: h.req("prefix_attn").as_bool().unwrap(),
                        objective: h.req("objective").as_str().unwrap().to_string(),
                    })
                    .collect();
                (k.clone(), vs)
            })
            .collect();
        let executables = v
            .req("executables")
            .as_obj()
            .context("executables")?
            .iter()
            .map(|(k, e)| {
                let args = e
                    .req("args")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|a| ArgSpec {
                        kind: a.req("kind").as_str().unwrap().to_string(),
                        name: a.req("name").as_str().unwrap().to_string(),
                        shape: a.get("shape").map(|s| s.usize_arr()).unwrap_or_default(),
                        dtype: a
                            .get("dtype")
                            .and_then(|d| d.as_str())
                            .unwrap_or("f32")
                            .to_string(),
                    })
                    .collect();
                let outputs = e
                    .req("outputs")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|o| {
                        (o.req("shape").usize_arr(),
                         o.req("dtype").as_str().unwrap().to_string())
                    })
                    .collect();
                (
                    k.clone(),
                    ExeSpec { file: e.req("file").as_str().unwrap().to_string(), args, outputs },
                )
            })
            .collect();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            vocab: v.req("vocab").as_usize().context("vocab")?,
            seq_max: v.req("seq_max").as_usize().context("seq_max")?,
            accept_max: v.req("accept_max").as_usize().context("accept_max")?,
            num_heads: v.req("num_heads").as_usize().context("num_heads")?,
            tree_buckets: v.req("tree_buckets").usize_arr(),
            batch_buckets: v
                .req("batch_buckets")
                .as_obj()
                .context("batch_buckets")?
                .iter()
                .map(|(k, a)| (k.clone(), a.usize_arr()))
                .collect(),
            hydra_m_buckets: v
                .req("hydra_m_buckets")
                .as_obj()
                .context("hydra_m_buckets")?
                .iter()
                .map(|(k, a)| (k.clone(), a.usize_arr()))
                .collect(),
            eagle_n_buckets: v.req("eagle_n_buckets").usize_arr(),
            sizes,
            head_variants,
            weight_files: v
                .req("weight_files")
                .as_obj()
                .context("weight_files")?
                .iter()
                .map(|(k, f)| (k.clone(), f.as_str().unwrap().to_string()))
                .collect(),
            executables,
        })
    }

    /// Dimensions of a model size.
    pub fn dims(&self, size: &str) -> Result<&ModelDims> {
        self.sizes.get(size).with_context(|| format!("unknown size `{size}`"))
    }

    /// Look up a head variant by (size, name).
    pub fn variant(&self, size: &str, name: &str) -> Result<&HeadVariant> {
        self.head_variants
            .get(size)
            .and_then(|vs| vs.iter().find(|v| v.name == name))
            .with_context(|| format!("no head variant `{name}` for size `{size}`"))
    }

    /// Smallest bucket >= n, or an error if none fits.
    pub fn bucket(buckets: &[usize], n: usize) -> Result<usize> {
        buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .with_context(|| format!("no bucket >= {n} in {buckets:?}"))
    }

    /// Smallest AOT tree bucket holding `n` nodes.
    pub fn tree_bucket(&self, n: usize) -> Result<usize> {
        Self::bucket(&self.tree_buckets, n)
    }

    /// Descriptor of a named executable.
    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables.get(name).with_context(|| format!("no executable `{name}`"))
    }

    /// Whether a named executable was built.
    pub fn has_exe(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Node capacity certified by the `*_masked_*` verify/commit aliases
    /// for `(size, batch)`, or `None` when the artifacts predate them.
    ///
    /// The aliases (emitted by `python/compile/aot.py`) point at the
    /// widest tree bucket and certify that the ancestor mask is a runtime
    /// *input* to verification — any topology of up to the returned node
    /// count runs in one call with padding rows inert, so the engine can
    /// pin a single bucket instead of climbing the `_t{N}` ladder. The
    /// capacity is read off the alias's own arg contract (`tokens`
    /// shape `[B, cap]`) and cross-checked against the commit alias's
    /// `tree_kv` shape `[B, L, 2, cap, KVD]`; any mismatch or missing
    /// alias disables masked mode (bucket-ladder fallback).
    pub fn masked_tree_cap(&self, size: &str, batch: usize) -> Option<usize> {
        let verify = self.executables.get(&format!("verify_masked_{size}_b{batch}"))?;
        let commit = self.executables.get(&format!("commit_masked_{size}_b{batch}"))?;
        let cap = verify
            .args
            .iter()
            .find(|a| a.kind == "dyn" && a.name == "tokens")
            .and_then(|a| a.shape.get(1).copied())?;
        let commit_cap = commit
            .args
            .iter()
            .find(|a| a.kind == "dyn" && a.name == "tree_kv")
            .and_then(|a| a.shape.get(3).copied())?;
        if cap == commit_cap && cap > 0 {
            Some(cap)
        } else {
            None
        }
    }

    /// As [`masked_tree_cap`](Self::masked_tree_cap), for the fused
    /// `verify_commit_masked_*` alias (capacity read from `tokens`,
    /// cross-checked against `prev_tree_kv`). The fused alias is emitted
    /// by `aot_extend.py` and may be absent even when the unfused masked
    /// aliases exist.
    pub fn masked_fused_cap(&self, size: &str, batch: usize) -> Option<usize> {
        let fused = self.executables.get(&format!("verify_commit_masked_{size}_b{batch}"))?;
        let cap = fused
            .args
            .iter()
            .find(|a| a.kind == "dyn" && a.name == "tokens")
            .and_then(|a| a.shape.get(1).copied())?;
        let prev_cap = fused
            .args
            .iter()
            .find(|a| a.kind == "dyn" && a.name == "prev_tree_kv")
            .and_then(|a| a.shape.get(3).copied())?;
        if cap == prev_cap && cap > 0 {
            Some(cap)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = vec![1, 4, 8, 16, 32, 64];
        assert_eq!(Manifest::bucket(&buckets, 1).unwrap(), 1);
        assert_eq!(Manifest::bucket(&buckets, 2).unwrap(), 4);
        assert_eq!(Manifest::bucket(&buckets, 16).unwrap(), 16);
        assert_eq!(Manifest::bucket(&buckets, 33).unwrap(), 64);
        assert!(Manifest::bucket(&buckets, 65).is_err());
    }

    fn exe(args: &[(&str, &[usize])]) -> ExeSpec {
        ExeSpec {
            file: "x.hlo.txt".into(),
            args: args
                .iter()
                .map(|(n, s)| ArgSpec {
                    kind: "dyn".into(),
                    name: n.to_string(),
                    shape: s.to_vec(),
                    dtype: "i32".into(),
                })
                .collect(),
            outputs: Vec::new(),
        }
    }

    fn manifest_with(exes: Vec<(&str, ExeSpec)>) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            vocab: 0,
            seq_max: 0,
            accept_max: 0,
            num_heads: 0,
            tree_buckets: vec![1, 8, 16],
            batch_buckets: BTreeMap::new(),
            hydra_m_buckets: BTreeMap::new(),
            eagle_n_buckets: Vec::new(),
            sizes: BTreeMap::new(),
            head_variants: BTreeMap::new(),
            weight_files: BTreeMap::new(),
            executables: exes.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        }
    }

    #[test]
    fn masked_cap_from_aliases() {
        let m = manifest_with(vec![
            ("verify_masked_s_b1", exe(&[("tokens", &[1, 16]), ("anc_mask", &[1, 16, 16])])),
            ("commit_masked_s_b1", exe(&[("kv", &[1, 2, 2, 64, 8]), ("tree_kv", &[1, 2, 2, 16, 8])])),
        ]);
        assert_eq!(m.masked_tree_cap("s", 1), Some(16));
        // Missing batch bucket / size → no capability.
        assert_eq!(m.masked_tree_cap("s", 2), None);
        assert_eq!(m.masked_tree_cap("m", 1), None);
        // No fused alias in this manifest.
        assert_eq!(m.masked_fused_cap("s", 1), None);
    }

    #[test]
    fn masked_cap_rejects_inconsistent_aliases() {
        // Verify and commit aliases certifying different capacities is a
        // broken artifact set — masked mode must stay off.
        let m = manifest_with(vec![
            ("verify_masked_s_b1", exe(&[("tokens", &[1, 16])])),
            ("commit_masked_s_b1", exe(&[("tree_kv", &[1, 2, 2, 8, 8])])),
        ]);
        assert_eq!(m.masked_tree_cap("s", 1), None);
    }

    #[test]
    fn masked_fused_cap_cross_checks_prev_tree_kv() {
        let m = manifest_with(vec![(
            "verify_commit_masked_s_b1",
            exe(&[("tokens", &[1, 16]), ("prev_tree_kv", &[1, 2, 2, 16, 8])]),
        )]);
        assert_eq!(m.masked_fused_cap("s", 1), Some(16));
        let bad = manifest_with(vec![(
            "verify_commit_masked_s_b1",
            exe(&[("tokens", &[1, 16]), ("prev_tree_kv", &[1, 2, 2, 8, 8])]),
        )]);
        assert_eq!(bad.masked_fused_cap("s", 1), None);
    }
}
