//! Adaptive speculation: per-slot dynamic draft trees plus a batch-aware
//! verification throttle.
//!
//! The paper's §4 evaluation (and Medusa's tree-construction analysis)
//! shows that the best draft-tree size depends on the acceptance rate of
//! the sequence being decoded and on how full the batch is: large trees
//! win at batch 1, but as the batch fills, verifying many nodes per slot
//! wastes base-model FLOPs on speculation that mostly gets rejected. A
//! static tree therefore charges every step the worst-case speculation
//! cost. This module turns that compile-time choice into a runtime
//! control loop:
//!
//! * [`TreeLadder`] — a small precomputed family of tree shapes
//!   T_1 ⊂ T_2 ⊂ … ⊂ T_N obtained by prefix-truncating the engine's
//!   (tuned or default) tree at increasing node budgets. Because
//!   [`TreeTopology`] stores its choice paths in canonical order
//!   (parents before children, sibling ranks contiguous), every prefix
//!   of the node list is itself a valid tree — the ladder inherits the
//!   §4-searched shape at every size.
//! * [`Adaptive`] — the per-slot controller. It tracks, per batch slot,
//!   an EMA of accepted-tokens-per-step and per-depth acceptance rates
//!   (with an optimistic prior so cold slots start from the largest
//!   tree, matching the batch-1 optimum), and each step selects the rung
//!   whose depth the acceptance statistics justify. A global throttle
//!   then shrinks the largest `auto` trees until the whole batch's
//!   verification cost fits a configurable per-step token budget — the
//!   batch-aware half of the loop.
//!
//! Under greedy acceptance the selected tree shape can only change
//! *speed*, never output (the accepted path is always the base model's
//! own greedy chain), so adaptive runs are token-identical to static
//! ones — asserted end-to-end by `tests/engine_e2e.rs` and
//! `benches/adaptive.rs`.
//!
//! The controller is pure policy: it owns no tensors and calls no
//! executables, so its behaviour is fully unit-tested without artifacts.
//! The engine feeds it observations from the verify/commit path and
//! consumes its per-slot rung choices (see `engine::step`).

use std::rc::Rc;

use crate::tree::TreeTopology;

/// Per-request speculation policy, carried on
/// [`SamplingParams`](crate::engine::SamplingParams).
///
/// Only consulted when the engine runs the adaptive controller
/// ([`crate::engine::Engine::enable_adaptive`]); a static-tree engine
/// verifies its configured tree for every slot regardless.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpeculationMode {
    /// Let the controller size this slot's draft tree from its online
    /// acceptance statistics (and the global throttle). The default.
    #[default]
    Auto,
    /// Pin the slot to the largest ladder rung of at most this many
    /// nodes. `Fixed(1)` is pure autoregressive decoding for this slot;
    /// fixed slots are never shrunk by the batch throttle.
    Fixed(usize),
}

impl SpeculationMode {
    /// Largest node count a `Fixed` pin may request — the sanity bound
    /// shared by the CLI and wire-protocol validators.
    pub const MAX_FIXED_NODES: usize = 1024;

    /// Parse the shared textual form used by both the CLI flag and the
    /// wire protocol: `"auto"`, or an integer node count in
    /// `[1, MAX_FIXED_NODES]`.
    pub fn parse(s: &str) -> Result<SpeculationMode, String> {
        if s == "auto" {
            return Ok(SpeculationMode::Auto);
        }
        match s.parse::<usize>() {
            Ok(k) if (1..=Self::MAX_FIXED_NODES).contains(&k) => Ok(SpeculationMode::Fixed(k)),
            _ => Err(format!(
                "expected `auto` or an integer in [1, {}], got `{s}`",
                Self::MAX_FIXED_NODES
            )),
        }
    }
}

impl std::fmt::Display for SpeculationMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpeculationMode::Auto => write!(f, "auto"),
            SpeculationMode::Fixed(n) => write!(f, "fixed({n})"),
        }
    }
}

/// A nested family of draft-tree shapes, ascending by node count. The
/// top rung is the engine's configured tree; deeper rungs are its
/// canonical-prefix truncations (see [`TreeTopology::truncate_prefix`]).
/// Rungs are `Rc`-shared so per-step selection hands out handles, not
/// deep topology clones.
#[derive(Debug, Clone)]
pub struct TreeLadder {
    /// Rungs in strictly increasing node count; `rungs[0]` is the 1-node
    /// (autoregressive) tree, the last rung is the full tree.
    pub rungs: Vec<Rc<TreeTopology>>,
}

impl TreeLadder {
    /// Build a ladder from the engine's full tree, keeping the requested
    /// node budgets that fall inside `[1, full.len()]` (deduplicated;
    /// the 1-node rung and the full tree are always included).
    pub fn from_tree(full: &TreeTopology, sizes: &[usize]) -> TreeLadder {
        let mut wanted: Vec<usize> = sizes
            .iter()
            .copied()
            .filter(|&n| n >= 1 && n < full.len())
            .chain([1, full.len()])
            .collect();
        wanted.sort_unstable();
        wanted.dedup();
        let rungs = wanted.iter().map(|&n| Rc::new(full.truncate_prefix(n))).collect();
        TreeLadder { rungs }
    }

    /// Number of rungs.
    pub fn len(&self) -> usize {
        self.rungs.len()
    }

    /// A ladder always has at least the 1-node rung.
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Index of the largest rung (the full tree).
    pub fn top(&self) -> usize {
        self.rungs.len() - 1
    }

    /// Node count of rung `r`.
    pub fn nodes_of(&self, r: usize) -> usize {
        self.rungs[r].len()
    }

    /// Tree depth of the deepest rung.
    pub fn max_depth(&self) -> usize {
        self.rungs[self.top()].max_depth()
    }

    /// Largest rung with at most `n` nodes (rung 0 — one node — always
    /// qualifies once `n` is clamped to at least 1).
    pub fn rung_for_nodes(&self, n: usize) -> usize {
        let n = n.max(1);
        (0..self.rungs.len()).rev().find(|&r| self.rungs[r].len() <= n).unwrap_or(0)
    }

    /// Largest (widest) rung whose depth does not exceed `d`.
    pub fn rung_for_depth(&self, d: usize) -> usize {
        let d = d.max(1);
        (0..self.rungs.len()).rev().find(|&r| self.rungs[r].max_depth() <= d).unwrap_or(0)
    }
}

/// Tuning knobs for the adaptive controller. The defaults are
/// conservative: no throttle until a budget is set, mild EMA smoothing,
/// and a 10% reach threshold for keeping a tree depth.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Per-step verification budget: the batch's selected trees are
    /// shrunk (largest `auto` tree first) until their total node count
    /// fits. At the controller level 0 disables the throttle;
    /// `Engine::enable_adaptive` resolves 0 (the default) to its
    /// batch-aware default budget, so engine callers pass `usize::MAX`
    /// to run unthrottled. Fixed-mode slots count toward the budget but
    /// are never shrunk.
    pub step_token_budget: usize,
    /// Keep extending the target depth while the estimated probability
    /// that the acceptance walk reaches it stays at or above this.
    pub min_reach: f64,
    /// Smoothing factor for the per-slot accepted-tokens-per-step EMA
    /// (weight of the newest observation).
    pub ema_alpha: f64,
    /// Requested rung node budgets (intersected with the actual tree
    /// size; 1 and the full size are always present).
    pub rung_sizes: Vec<usize>,
    /// A slot parked below the top rung probes a one-depth-deeper tree
    /// every this many steps, so a sequence that turns easy can climb
    /// back up the ladder (per-depth rates only update at depths the
    /// current tree reaches).
    pub probe_every: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> AdaptiveConfig {
        AdaptiveConfig {
            step_token_budget: 0,
            min_reach: 0.1,
            ema_alpha: 0.25,
            rung_sizes: vec![1, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64],
            probe_every: 16,
        }
    }
}

/// Per-slot online acceptance statistics.
#[derive(Debug, Clone)]
struct SlotStats {
    /// EMA of accepted tokens per step (the root token counts, so >= 1
    /// in steady state). Initialized optimistically to the ladder's max
    /// depth so cold slots start from the largest tree.
    ema: f64,
    /// attempts[d]: steps where this slot's tree had depth >= d (d is
    /// 1-based; index 0/1 unused — the root is always accepted).
    attempts: Vec<u64>,
    /// accepts[d]: steps where the acceptance walk reached depth d.
    accepts: Vec<u64>,
    /// Steps spent parked below the top rung since the last deep probe
    /// (re-probing applies at every parked depth, not just the AR rung).
    since_probe: u64,
}

impl SlotStats {
    fn fresh(max_depth: usize) -> SlotStats {
        SlotStats {
            ema: max_depth as f64,
            attempts: vec![0; max_depth + 1],
            accepts: vec![0; max_depth + 1],
            since_probe: 0,
        }
    }

    /// Acceptance rate at depth `d` with an optimistic +1/+1 prior:
    /// untested depths look perfect, so the controller explores them.
    fn rate(&self, d: usize) -> f64 {
        (self.accepts[d] + 1) as f64 / (self.attempts[d] + 1) as f64
    }
}

/// Aggregate controller counters (monotonic over the engine's life).
#[derive(Debug, Clone, Copy, Default)]
pub struct AdaptiveTotals {
    /// Throttle demotions applied (rung downgrades to fit the budget).
    pub throttled: u64,
    /// Controller selection passes (== engine decode steps).
    pub selections: u64,
}

/// Point-in-time view of the controller for observability frames.
#[derive(Debug, Clone)]
pub struct AdaptiveSnapshot {
    /// Currently selected tree node count per batch slot (stale entries
    /// for vacant slots — pair with engine occupancy when rendering).
    pub tree_nodes: Vec<usize>,
    /// The configured per-step verification budget (0 = unlimited).
    pub step_token_budget: usize,
    /// Node counts of the ladder rungs.
    pub ladder: Vec<usize>,
    /// Aggregate controller counters.
    pub totals: AdaptiveTotals,
}

/// The per-slot adaptive speculation controller. Pure policy: the engine
/// feeds it acceptance observations ([`Adaptive::observe`]) and asks it
/// to (re)select per-slot rungs each step ([`Adaptive::select`]).
#[derive(Debug, Clone)]
pub struct Adaptive {
    /// The tree family selection happens over.
    pub ladder: TreeLadder,
    /// Tuning knobs (budget, thresholds, smoothing).
    pub cfg: AdaptiveConfig,
    /// Current rung choice per batch slot.
    pub choice: Vec<usize>,
    slots: Vec<SlotStats>,
    totals: AdaptiveTotals,
}

impl Adaptive {
    /// Controller for `batch` slots over `ladder`, all slots cold.
    pub fn new(ladder: TreeLadder, cfg: AdaptiveConfig, batch: usize) -> Adaptive {
        let md = ladder.max_depth();
        let top = ladder.top();
        Adaptive {
            ladder,
            cfg,
            choice: vec![top; batch],
            slots: vec![SlotStats::fresh(md); batch],
            totals: AdaptiveTotals::default(),
        }
    }

    /// Reset slot `i` for a newly admitted request: statistics cleared
    /// to the optimistic prior, rung chosen from the request's mode
    /// (the next `select` pass applies the batch throttle).
    pub fn reset_slot(&mut self, i: usize, mode: SpeculationMode) {
        self.slots[i] = SlotStats::fresh(self.ladder.max_depth());
        self.choice[i] = match mode {
            SpeculationMode::Auto => self.ladder.top(),
            SpeculationMode::Fixed(n) => self.ladder.rung_for_nodes(n),
        };
    }

    /// Feed one step's outcome for slot `i`: the depth of the tree that
    /// was verified and the length of the accepted path (root included —
    /// acceptance length == depth reached).
    pub fn observe(&mut self, i: usize, used_depth: usize, accepted: usize) {
        let a = self.cfg.ema_alpha;
        let s = &mut self.slots[i];
        s.ema = a * accepted as f64 + (1.0 - a) * s.ema;
        for d in 2..=used_depth.min(s.attempts.len() - 1) {
            s.attempts[d] += 1;
            if accepted >= d {
                s.accepts[d] += 1;
            }
        }
    }

    /// Desired rung for an `auto` slot, before the batch throttle: the
    /// widest rung whose depth both (a) the per-depth acceptance rates
    /// say the walk still reaches with probability >= `min_reach`, and
    /// (b) does not outrun the slot's pace (EMA + 1 level of headroom).
    fn desired_rung(&mut self, i: usize) -> usize {
        let top_depth = self.ladder.max_depth();
        let s = &self.slots[i];
        // rate(d) already estimates the UNCONDITIONAL frequency of the
        // walk reaching depth d (accepts[d] counts whole-walk outcomes),
        // so it is compared to min_reach directly — multiplying rates
        // across depths would double-count and demote far too early.
        let mut depth = 1usize;
        for d in 2..=top_depth {
            if s.rate(d) < self.cfg.min_reach {
                break;
            }
            depth = d;
        }
        let pace = (s.ema + 1.0).ceil() as usize;
        let target = depth.min(pace.max(2)).clamp(1, top_depth);
        let mut rung = self.ladder.rung_for_depth(target);
        // Parked below the top rung: periodically probe one depth deeper
        // so a sequence that turns easy can climb back up. Necessary at
        // EVERY parked depth, not just the AR rung — per-depth rates are
        // only updated at depths the current tree reaches, so without
        // probing a demotion to depth d could never re-test depth d+1.
        let s = &mut self.slots[i];
        if rung < self.ladder.top() {
            s.since_probe += 1;
            if s.since_probe >= self.cfg.probe_every {
                s.since_probe = 0;
                let deeper = self.ladder.rungs[rung].max_depth() + 1;
                rung = self.ladder.rung_for_depth(deeper.min(self.ladder.max_depth()));
            }
        } else {
            s.since_probe = 0;
        }
        rung
    }

    /// One selection pass over the batch. `modes[i]` is the speculation
    /// mode of the active request in slot `i`, `None` for vacant/done
    /// slots (their choice is left untouched and does not count toward
    /// the budget). Deterministic: same statistics in, same choices out.
    pub fn select(&mut self, modes: &[Option<SpeculationMode>]) {
        self.totals.selections += 1;
        for (i, m) in modes.iter().enumerate() {
            let Some(mode) = m else { continue };
            self.choice[i] = match mode {
                SpeculationMode::Fixed(n) => self.ladder.rung_for_nodes(*n),
                SpeculationMode::Auto => self.desired_rung(i),
            };
        }
        // Batch-aware throttle: shrink the largest auto tree (ties:
        // lowest slot index) until the batch fits the budget. Fixed
        // slots count toward the total but are never demoted. Cost is
        // counted in REAL selected nodes (`ladder.nodes_of`), never in
        // AOT bucket padding — under the engine's mask-parameterized
        // verification every step runs a pinned wide bucket whose
        // padding rows are inert, so bucket size says nothing about
        // speculation spend.
        let budget = self.cfg.step_token_budget;
        if budget == 0 {
            return;
        }
        let mut total: usize = modes
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_some())
            .map(|(i, _)| self.ladder.nodes_of(self.choice[i]))
            .sum();
        while total > budget {
            let mut best: Option<(usize, usize)> = None; // (nodes, slot)
            for (i, m) in modes.iter().enumerate() {
                if matches!(m, Some(SpeculationMode::Auto)) && self.choice[i] > 0 {
                    let n = self.ladder.nodes_of(self.choice[i]);
                    if best.map_or(true, |(bn, _)| n > bn) {
                        best = Some((n, i));
                    }
                }
            }
            let Some((n, i)) = best else { break };
            self.choice[i] -= 1;
            total -= n - self.ladder.nodes_of(self.choice[i]);
            self.totals.throttled += 1;
        }
    }

    /// Currently selected node count per slot.
    pub fn tree_nodes(&self) -> Vec<usize> {
        self.choice.iter().map(|&r| self.ladder.nodes_of(r)).collect()
    }

    /// Current EMA of accepted tokens per step for slot `i` (tests and
    /// observability).
    pub fn ema_accept(&self, i: usize) -> f64 {
        self.slots[i].ema
    }

    /// Observability snapshot for the server's `{"op":"stats"}` frame.
    pub fn snapshot(&self) -> AdaptiveSnapshot {
        AdaptiveSnapshot {
            tree_nodes: self.tree_nodes(),
            step_token_budget: self.cfg.step_token_budget,
            ladder: self.ladder.rungs.iter().map(|t| t.len()).collect(),
            totals: self.totals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::{prop_assert, prop_assert_eq};

    fn ladder32() -> TreeLadder {
        TreeLadder::from_tree(&TreeTopology::default_tree(32), &AdaptiveConfig::default().rung_sizes)
    }

    #[test]
    fn ladder_is_strictly_increasing_and_bounded() {
        let full = TreeTopology::default_tree(32);
        let l = TreeLadder::from_tree(&full, &[1, 2, 4, 8, 16, 64, 0]);
        assert_eq!(l.nodes_of(0), 1);
        assert_eq!(l.nodes_of(l.top()), full.len());
        for w in l.rungs.windows(2) {
            assert!(w[0].len() < w[1].len(), "ladder must strictly increase");
        }
        // Every rung is a canonical-prefix subtree of the full tree.
        for r in &l.rungs {
            assert_eq!(r.paths[..], full.paths[..r.len() - 1]);
        }
    }

    #[test]
    fn ladder_rung_selectors() {
        let l = ladder32();
        assert_eq!(l.nodes_of(l.rung_for_nodes(0)), 1);
        assert_eq!(l.nodes_of(l.rung_for_nodes(1)), 1);
        for n in [2usize, 5, 9, 100] {
            assert!(l.nodes_of(l.rung_for_nodes(n)) <= n.max(1));
        }
        assert_eq!(l.rung_for_nodes(usize::MAX), l.top());
        assert_eq!(l.rungs[l.rung_for_depth(1)].max_depth(), 1);
        for d in 2..=l.max_depth() {
            assert!(l.rungs[l.rung_for_depth(d)].max_depth() <= d);
        }
        assert_eq!(l.rung_for_depth(99), l.top());
    }

    #[test]
    fn cold_auto_slot_starts_at_the_top() {
        let mut a = Adaptive::new(ladder32(), AdaptiveConfig::default(), 4);
        a.select(&[Some(SpeculationMode::Auto), None, None, None]);
        assert_eq!(a.choice[0], a.ladder.top(), "optimistic prior must pick the full tree");
        // Vacant slots are untouched.
        assert_eq!(a.choice[1], a.ladder.top());
    }

    #[test]
    fn fixed_mode_pins_the_rung() {
        let mut a = Adaptive::new(ladder32(), AdaptiveConfig::default(), 2);
        let modes = [Some(SpeculationMode::Fixed(1)), Some(SpeculationMode::Fixed(6))];
        a.select(&modes);
        assert_eq!(a.ladder.nodes_of(a.choice[0]), 1, "fixed(1) is pure AR");
        assert!(a.ladder.nodes_of(a.choice[1]) <= 6);
        // Fixed choices survive arbitrary observations.
        for _ in 0..50 {
            a.observe(0, 1, 1);
            a.observe(1, 4, 1);
            a.select(&modes);
        }
        assert_eq!(a.ladder.nodes_of(a.choice[0]), 1);
        assert!(a.ladder.nodes_of(a.choice[1]) <= 6);
    }

    #[test]
    fn poor_acceptance_shrinks_the_tree() {
        let mut a = Adaptive::new(ladder32(), AdaptiveConfig::default(), 1);
        let modes = [Some(SpeculationMode::Auto)];
        a.select(&modes);
        let start = a.ladder.nodes_of(a.choice[0]);
        // Hard sequence: only the root is ever accepted.
        for _ in 0..40 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, 1);
            a.select(&modes);
        }
        let end = a.ladder.nodes_of(a.choice[0]);
        assert!(end < start, "tree must shrink under rejection: {start} -> {end}");
        assert!(a.ema_accept(0) < 1.5, "EMA must converge toward 1");
    }

    #[test]
    fn good_acceptance_keeps_or_recovers_depth() {
        let cfg = AdaptiveConfig { probe_every: 4, ..AdaptiveConfig::default() };
        let mut a = Adaptive::new(ladder32(), cfg, 1);
        let modes = [Some(SpeculationMode::Auto)];
        // Force the slot down first (a probe step may be in flight, so
        // assert "near the bottom" rather than exactly 1 node).
        for _ in 0..60 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, 1);
            a.select(&modes);
        }
        assert!(
            a.ladder.rungs[a.choice[0]].max_depth() <= 2,
            "hard sequence must be parked at the bottom of the ladder"
        );
        // The sequence turns easy: every probe fully accepts. The slot
        // must climb back off the AR rung.
        let mut climbed = false;
        for _ in 0..200 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, used);
            a.select(&modes);
            if a.ladder.nodes_of(a.choice[0]) > 1 {
                climbed = true;
            }
        }
        assert!(climbed, "probe steps must let an easy sequence recover depth");
        assert!(
            a.ladder.rungs[a.choice[0]].max_depth() >= 2,
            "recovered slot should hold depth >= 2"
        );
    }

    #[test]
    fn throttle_fits_the_budget_and_spares_fixed_slots() {
        let ladder = ladder32();
        let full = ladder.nodes_of(ladder.top());
        let budget = full + 6; // room for one full tree plus change
        let cfg = AdaptiveConfig { step_token_budget: budget, ..AdaptiveConfig::default() };
        let mut a = Adaptive::new(ladder, cfg, 4);
        let modes = [
            Some(SpeculationMode::Auto),
            Some(SpeculationMode::Auto),
            Some(SpeculationMode::Fixed(4)),
            Some(SpeculationMode::Auto),
        ];
        a.select(&modes);
        let total: usize = (0..4).map(|i| a.ladder.nodes_of(a.choice[i])).sum();
        assert!(total <= budget, "throttle must fit the budget: {total} > {budget}");
        assert!(
            a.ladder.nodes_of(a.choice[2]) > 1 && a.ladder.nodes_of(a.choice[2]) <= 4,
            "fixed slot must keep its rung"
        );
        assert!(a.snapshot().totals.throttled > 0);
    }

    #[test]
    fn throttle_off_leaves_choices_alone() {
        let mut a = Adaptive::new(ladder32(), AdaptiveConfig::default(), 8);
        let modes: Vec<_> = (0..8).map(|_| Some(SpeculationMode::Auto)).collect();
        a.select(&modes);
        for i in 0..8 {
            assert_eq!(a.choice[i], a.ladder.top(), "no budget -> every cold slot at the top");
        }
    }

    #[test]
    fn reset_slot_restores_optimism() {
        let mut a = Adaptive::new(ladder32(), AdaptiveConfig::default(), 1);
        let modes = [Some(SpeculationMode::Auto)];
        for _ in 0..40 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, 1);
            a.select(&modes);
        }
        assert!(a.ladder.nodes_of(a.choice[0]) < a.ladder.nodes_of(a.ladder.top()));
        a.reset_slot(0, SpeculationMode::Auto);
        a.select(&modes);
        assert_eq!(a.choice[0], a.ladder.top(), "a new occupant must start cold/optimistic");
    }

    #[test]
    fn speculation_mode_display() {
        assert_eq!(SpeculationMode::Auto.to_string(), "auto");
        assert_eq!(SpeculationMode::Fixed(3).to_string(), "fixed(3)");
    }

    #[test]
    fn speculation_mode_parse_shared_by_cli_and_proto() {
        assert_eq!(SpeculationMode::parse("auto"), Ok(SpeculationMode::Auto));
        assert_eq!(SpeculationMode::parse("1"), Ok(SpeculationMode::Fixed(1)));
        assert_eq!(SpeculationMode::parse("1024"), Ok(SpeculationMode::Fixed(1024)));
        for bad in ["0", "1025", "-2", "2.5", "fast", ""] {
            assert!(SpeculationMode::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn parked_slot_reprobes_one_depth_deeper() {
        // A slot stuck at an INTERMEDIATE depth (not just the AR rung)
        // must periodically re-test the next depth, otherwise its
        // per-depth rates freeze and it can never climb back.
        let cfg = AdaptiveConfig { probe_every: 3, ..AdaptiveConfig::default() };
        let mut a = Adaptive::new(ladder32(), cfg, 1);
        let modes = [Some(SpeculationMode::Auto)];
        // Accept exactly 2/step: the slot settles around depth 2-3.
        for _ in 0..40 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, 2.min(used));
            a.select(&modes);
        }
        let settled = a.ladder.rungs[a.choice[0]].max_depth();
        assert!(settled < a.ladder.max_depth(), "must be parked below the top");
        // Now the sequence turns perfectly easy: probes must carry the
        // slot strictly deeper than where it settled.
        let mut deepest = settled;
        for _ in 0..100 {
            let used = a.ladder.rungs[a.choice[0]].max_depth();
            a.observe(0, used, used);
            a.select(&modes);
            deepest = deepest.max(a.ladder.rungs[a.choice[0]].max_depth());
        }
        assert!(
            deepest > settled,
            "re-probing must let an easy sequence climb past depth {settled}"
        );
    }

    #[test]
    fn prop_throttle_never_exceeds_feasible_budget() {
        prop::check("adaptive-throttle", 150, |rng| {
            let full = TreeTopology::default_tree(rng.range(1, 40));
            let ladder = TreeLadder::from_tree(&full, &[1, 2, 4, 8, 16, 24]);
            let batch = rng.range(1, 10);
            let active: Vec<Option<SpeculationMode>> = (0..batch)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        None
                    } else if rng.f64() < 0.25 {
                        Some(SpeculationMode::Fixed(rng.range(1, 8)))
                    } else {
                        Some(SpeculationMode::Auto)
                    }
                })
                .collect();
            let n_active = active.iter().filter(|m| m.is_some()).count();
            // Feasible budget: every active slot can shrink to >= 1 node,
            // but fixed slots stop at their pinned size.
            let fixed_floor: usize = active
                .iter()
                .filter_map(|m| match m {
                    Some(SpeculationMode::Fixed(n)) => {
                        Some(ladder.nodes_of(ladder.rung_for_nodes(*n)))
                    }
                    _ => None,
                })
                .sum();
            let auto_count = active
                .iter()
                .filter(|m| matches!(m, Some(SpeculationMode::Auto)))
                .count();
            let budget = fixed_floor + auto_count + rng.range(0, 16);
            let cfg = AdaptiveConfig { step_token_budget: budget, ..AdaptiveConfig::default() };
            let mut a = Adaptive::new(ladder, cfg, batch);
            // Random warm-up observations.
            for _ in 0..rng.range(0, 30) {
                let i = rng.below(batch);
                let used = a.ladder.rungs[a.choice[i]].max_depth();
                let acc = rng.range(1, used + 1);
                a.observe(i, used, acc);
                a.select(&active);
            }
            a.select(&active);
            let total: usize = active
                .iter()
                .enumerate()
                .filter(|(_, m)| m.is_some())
                .map(|(i, _)| a.ladder.nodes_of(a.choice[i]))
                .sum();
            prop_assert!(
                total <= budget,
                "throttled total {total} exceeds feasible budget {budget} ({n_active} active)"
            );
            Ok(())
        });
    }
}
