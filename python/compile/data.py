"""Deterministic synthetic corpus generator.

Stands in for ShareGPT (training) / MT-Bench (eval) / SpecBench (Table 2) —
see DESIGN.md §2. The corpus is drawn from a probabilistic grammar with
strong local statistics so that (a) a tiny base LM learns a sharp next-token
distribution, and (b) draft heads face the paper's actual learning problem:
predicting the *base model* several tokens ahead. Six task categories mirror
SpecBench's split: chat, translation, summary, qa, math, rag.

Everything is seeded; `make artifacts` is reproducible byte-for-byte.
"""

import json
import random
from typing import Dict, List, Tuple

CATEGORIES = ["chat", "translation", "summary", "qa", "math", "rag"]

NAMES = [
    "alice", "bob", "carol", "dave", "erin", "frank", "grace", "heidi",
    "ivan", "judy", "karl", "lena", "mike", "nina", "oscar", "peggy",
]
CITIES = [
    "paris", "london", "tokyo", "cairo", "lima", "oslo", "delhi", "rome",
    "kyiv", "quito", "hanoi", "accra", "sofia", "dakar", "perth", "bern",
]
ANIMALS = [
    "otter", "heron", "lynx", "ibis", "tapir", "gecko", "bison", "stork",
    "viper", "moth", "crane", "skink", "finch", "koala", "dingo", "squid",
]
COLORS = ["red", "blue", "green", "amber", "violet", "teal", "coral", "gray"]
FOODS = ["rice", "soup", "bread", "mango", "pasta", "beans", "salad", "dates"]
VERBS = ["likes", "keeps", "feeds", "draws", "finds", "meets", "sees", "helps"]

# Deterministic word-level "cipher language" for the translation category:
# every source word maps to a fixed pseudo-word, so translation is a pure
# memorization task a small model can master — like the paper's translation
# split, it is the *most* predictable category.
_CIPHER_SYLLABLES = ["za", "mo", "ki", "tu", "re", "pa", "vo", "ne", "lu", "si"]


def _cipher_word(word: str) -> str:
    h = 0
    for ch in word:
        h = (h * 31 + ord(ch)) % (10**6)
    out = []
    for _ in range(max(2, min(3, len(word) // 2))):
        out.append(_CIPHER_SYLLABLES[h % 10])
        h //= 10
    return "".join(out)


def _sentence(rng: random.Random) -> str:
    n, v = rng.choice(NAMES), rng.choice(VERBS)
    obj = rng.choice([rng.choice(ANIMALS), rng.choice(FOODS)])
    if rng.random() < 0.5:
        return f"{n} {v} the {rng.choice(COLORS)} {obj}"
    return f"{n} {v} {obj} in {rng.choice(CITIES)}"


def _gen_chat(rng: random.Random) -> Tuple[str, str]:
    name = rng.choice(NAMES)
    city = rng.choice(CITIES)
    animal = rng.choice(ANIMALS)
    color = rng.choice(COLORS)
    templates = [
        (
            f"tell me about {name}.",
            f"{name} lives in {city} and {rng.choice(VERBS)} the {color} {animal}. "
            f"every day {name} walks in {city} and feeds the {animal}.",
        ),
        (
            f"describe a day for {name} in {city}.",
            f"in the morning {name} eats {rng.choice(FOODS)}. then {name} "
            f"{rng.choice(VERBS)} the {animal}. at night {name} rests in {city}.",
        ),
        (
            f"who is {name}?",
            f"{name} is from {city}. {name} {rng.choice(VERBS)} the {color} "
            f"{animal} and eats {rng.choice(FOODS)}.",
        ),
    ]
    return rng.choice(templates)


def _gen_translation(rng: random.Random) -> Tuple[str, str]:
    src = _sentence(rng)
    tgt = " ".join(_cipher_word(w) for w in src.split())
    return (f"translate to zamo: {src}", tgt)


def _gen_summary(rng: random.Random) -> Tuple[str, str]:
    sents = [_sentence(rng) for _ in range(rng.randint(3, 5))]
    passage = ". ".join(sents) + "."
    # Extractive summary: first and last sentence — a copy task, like the
    # paper's summarization split (low speedup: long low-entropy spans are
    # rare relative to chat).
    summary = sents[0] + ". " + sents[-1] + "."
    return (f"summarize: {passage}", summary)


def _gen_qa(rng: random.Random) -> Tuple[str, str]:
    name = rng.choice(NAMES)
    city = rng.choice(CITIES)
    animal = rng.choice(ANIMALS)
    kind = rng.randrange(3)
    if kind == 0:
        return (f"where does {name} live? {name} lives in {city}.", f"{name} lives in {city}.")
    if kind == 1:
        return (
            f"fact: {name} keeps the {animal}. what does {name} keep?",
            f"{name} keeps the {animal}.",
        )
    return (
        f"fact: the {animal} is in {city}. where is the {animal}?",
        f"the {animal} is in {city}.",
    )


def _gen_math(rng: random.Random) -> Tuple[str, str]:
    kind = rng.randrange(3)
    if kind == 0:
        a, b = rng.randint(0, 99), rng.randint(0, 99)
        return (f"compute {a} + {b}.", f"{a} + {b} = {a + b}.")
    if kind == 1:
        a, b = rng.randint(0, 20), rng.randint(0, 20)
        return (f"compute {a} * {b}.", f"{a} * {b} = {a * b}.")
    a = rng.randint(2, 30)
    seq = " ".join(str(a + i) for i in range(5))
    return (f"count from {a}: ", f"{seq} {a + 5} {a + 6}.")


def _gen_rag(rng: random.Random) -> Tuple[str, str]:
    docs = [_sentence(rng) for _ in range(3)]
    i = rng.randrange(3)
    subj = docs[i].split()[0]
    ctx = " | ".join(docs)
    return (
        f"context: {ctx}. question: what about {subj}?",
        f"{docs[i]}.",
    )


_GENERATORS = {
    "chat": _gen_chat,
    "translation": _gen_translation,
    "summary": _gen_summary,
    "qa": _gen_qa,
    "math": _gen_math,
    "rag": _gen_rag,
}

# Training mix: chat-heavy like ShareGPT, with every category represented.
_TRAIN_MIX = {
    "chat": 0.40, "translation": 0.12, "summary": 0.12,
    "qa": 0.14, "math": 0.12, "rag": 0.10,
}


def gen_example(rng: random.Random, category: str) -> Dict[str, str]:
    prompt, answer = _GENERATORS[category](rng)
    return {"category": category, "prompt": prompt, "answer": answer}


def format_turn(prompt: str, answer: str) -> str:
    """Single chat turn in the serving wire format (mirrored in Rust)."""
    return f"<user> {prompt} <bot> {answer} <end> "


def gen_corpus(seed: int = 1234, n_examples: int = 9000) -> str:
    """Training text: a stream of (possibly multi-turn) conversations."""
    rng = random.Random(seed)
    cats, weights = zip(*_TRAIN_MIX.items())
    parts: List[str] = []
    for _ in range(n_examples):
        category = rng.choices(cats, weights)[0]
        turns = rng.randint(1, 2) if category == "chat" else 1
        for _ in range(turns):
            ex = gen_example(rng, category)
            parts.append(format_turn(ex["prompt"], ex["answer"]))
    return "".join(parts)


def gen_eval_prompts(seed: int = 9876, per_category: int = 24) -> List[Dict[str, str]]:
    """Held-out prompts. `chat` doubles as MT-Bench-sim; the category-tagged
    full set is SpecBench-sim (Table 2). Disjoint seed from training."""
    rng = random.Random(seed)
    out: List[Dict[str, str]] = []
    for category in CATEGORIES:
        for i in range(per_category):
            ex = gen_example(rng, category)
            ex["id"] = f"{category}-{i}"
            out.append(ex)
    return out


def write_prompts(path: str, prompts: List[Dict[str, str]]) -> None:
    with open(path, "w") as f:
        json.dump(prompts, f, indent=1)
