"""Pallas tree-attention kernel — the verification hot-spot (L1).

Speculative tree verification packs all candidate-tree tokens into one
forward pass; each tree token attends to (a) the committed KV-cache prefix
and (b) its ancestors inside the tree (paper §2, "Tree decoding"). This
kernel fuses both mask terms into a flash-style online-softmax accumulator
so the [T, S+T] mask never materializes in HBM.

Hardware adaptation (DESIGN.md §7): the paper's GPU framing (threadblock
per query tile, shared-memory KV staging) maps on TPU to a grid over
(batch, head, query-tile) with the committed cache streamed HBM→VMEM in
`blk_s` chunks inside a fori_loop — the double-buffered analogue of the
shared-memory pipeline — and the MXU doing the [blk_t, hd] x [hd, blk_s]
products. `interpret=True` executes the same schedule on the CPU PJRT
plugin (real-TPU lowering would emit a Mosaic custom-call the CPU cannot
run).

Layouts are head-major to give the kernel contiguous [len, hd] panels:
  q:       [B, H,   T, hd]   (RoPE already applied)
  cache_k: [B, KVH, S, hd]   committed prefix keys (only [:cur_len] valid)
  tree_k:  [B, KVH, T, hd]   keys of the packed tree tokens
  anc_mask:[B, T, T]         anc_mask[i, j] = j is ancestor-or-self of i
  cur_len: [B, 1] i32
  out:     [B, H, T, hd]
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _tree_attn_kernel(q_ref, ck_ref, cv_ref, tk_ref, tv_ref, len_ref, mask_ref,
                      o_ref, *, blk_s: int, scale: float):
    blk_t, hd = q_ref.shape[2], q_ref.shape[3]
    s_total = ck_ref.shape[2]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # [blk_t, hd]
    cur_len = len_ref[0, 0]

    m0 = jnp.full((blk_t, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_t, 1), jnp.float32)
    a0 = jnp.zeros((blk_t, hd), jnp.float32)

    def prefix_block(i, carry):
        m, l, acc = carry
        k = ck_ref[0, 0, pl.ds(i * blk_s, blk_s), :].astype(jnp.float32)
        v = cv_ref[0, 0, pl.ds(i * blk_s, blk_s), :].astype(jnp.float32)
        pos = i * blk_s + jax.lax.broadcasted_iota(jnp.int32, (1, blk_s), 1)
        logits = q @ k.T                                  # [blk_t, blk_s] (MXU)
        logits = jnp.where(pos < cur_len, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)
        return (m_new, l * alpha + p.sum(-1, keepdims=True), acc * alpha + p @ v)

    m, l, acc = jax.lax.fori_loop(0, s_total // blk_s, prefix_block, (m0, l0, a0))

    # Final block: the tree tokens themselves, masked by ancestry. Every node
    # is its own ancestor, so each row has >= 1 valid key and l > 0.
    k = tk_ref[0, 0].astype(jnp.float32)                  # [T, hd]
    v = tv_ref[0, 0].astype(jnp.float32)
    logits = q @ k.T                                      # [blk_t, T]
    logits = jnp.where(mask_ref[0] != 0, logits, NEG_INF)
    m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(logits - m_new)
    l = l * alpha + p.sum(-1, keepdims=True)
    acc = acc * alpha + p @ v

    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def tree_attention(q, cache_k, cache_v, tree_k, tree_v, cur_len, anc_mask,
                   *, blk_s: int = 128, interpret: bool = True):
    """See module docstring for layouts. Returns [B, H, T, hd]."""
    b, h, t, hd = q.shape
    kvh, s_total = cache_k.shape[1], cache_k.shape[2]
    assert h % kvh == 0, (h, kvh)
    groups = h // kvh
    assert s_total % blk_s == 0, (s_total, blk_s)
    blk_t = t if t <= 16 else 16
    assert t % blk_t == 0, (t, blk_t)
    scale = 1.0 / (hd ** 0.5)
    mask_i32 = anc_mask.astype(jnp.int32)

    grid = (b, h, t // blk_t)
    kernel = functools.partial(_tree_attn_kernel, blk_s=blk_s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_t, hd), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, s_total, hd), lambda bi, hi, ti, g=groups: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, s_total, hd), lambda bi, hi, ti, g=groups: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda bi, hi, ti, g=groups: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, t, hd), lambda bi, hi, ti, g=groups: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ti: (bi, 0)),
            pl.BlockSpec((1, blk_t, t), lambda bi, hi, ti: (bi, ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_t, hd), lambda bi, hi, ti: (bi, hi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, hd), q.dtype),
        interpret=interpret,
    )(q, cache_k, cache_v, tree_k, tree_v, cur_len, mask_i32)


def tree_attention_batched_ref_layout(q_thd, cache_k, cache_v, tree_k, tree_v,
                                      cur_len, anc_mask, **kw):
    """Convenience wrapper taking the oracle's [T, H, hd] single-sequence
    layout (used by the hypothesis tests for direct comparison)."""
    q = q_thd.transpose(1, 0, 2)[None]                   # [1, H, T, hd]
    ck = cache_k.transpose(1, 0, 2)[None]
    cv = cache_v.transpose(1, 0, 2)[None]
    tk = tree_k.transpose(1, 0, 2)[None]
    tv = tree_v.transpose(1, 0, 2)[None]
    ln = jnp.reshape(cur_len.astype(jnp.int32), (1, 1))
    out = tree_attention(q, ck, cv, tk, tv, ln, anc_mask[None], **kw)
    return out[0].transpose(1, 0, 2)                     # [T, H, hd]
