"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: python/tests/test_kernels.py sweeps
shapes/dtypes with hypothesis and asserts the Pallas implementations match
these to tight tolerances. They are also the implementation used by the
(cold) prefill path, where kernel-level tiling does not matter.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[S, KVH, hd] -> [S, KVH*groups, hd] (GQA key/value head broadcast)."""
    return jnp.repeat(x, groups, axis=1)


def tree_attention_ref(
    q: jnp.ndarray,        # [T, H, hd]   (RoPE already applied)
    cache_k: jnp.ndarray,  # [S, KVH, hd]
    cache_v: jnp.ndarray,  # [S, KVH, hd]
    tree_k: jnp.ndarray,   # [T, KVH, hd]
    tree_v: jnp.ndarray,   # [T, KVH, hd]
    cur_len: jnp.ndarray,  # scalar i32 — valid prefix length in the cache
    anc_mask: jnp.ndarray, # [T, T] bool/0-1 — anc_mask[i, j] = node j is an
                           #   ancestor-or-self of node i in the candidate tree
) -> jnp.ndarray:          # [T, H, hd]
    """Attention of packed candidate-tree queries over committed-prefix KV
    plus in-tree ancestor KV. This is the verification hot-spot (§2 "Tree
    decoding" of the paper): one base-model forward scores the whole tree.
    """
    t, h, hd = q.shape
    s = cache_k.shape[0]
    kvh = cache_k.shape[1]
    groups = h // kvh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, dtype=q.dtype))

    k = jnp.concatenate([repeat_kv(cache_k, groups), repeat_kv(tree_k, groups)], axis=0)
    v = jnp.concatenate([repeat_kv(cache_v, groups), repeat_kv(tree_v, groups)], axis=0)

    # [T, H, S+T]
    logits = jnp.einsum("thd,shd->ths", q, k) * scale
    prefix_ok = jnp.arange(s)[None, :] < cur_len              # [1, S]
    prefix_ok = jnp.broadcast_to(prefix_ok, (t, s))
    tree_ok = anc_mask.astype(bool)                           # [T, T]
    mask = jnp.concatenate([prefix_ok, tree_ok], axis=1)      # [T, S+T]
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("ths,shd->thd", probs, v)


def swiglu_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray) -> jnp.ndarray:
    """LLaMA SwiGLU MLP: w2( silu(x@w1) * (x@w3) ).  x: [N, D]."""
    a = x @ w1
    g = a * jnp.reciprocal(1.0 + jnp.exp(-a))  # silu
    return (g * (x @ w3)) @ w2
