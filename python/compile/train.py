"""Build-time training: base LM + draft heads (all variants).

Runs once inside `make artifacts`, on CPU, single-core. The optimizer is a
hand-rolled AdamW with cosine LR + warmup (no optax in this environment),
matching the paper's recipe (§5: AdamW β1=0.9 β2=0.999, peak LR 1e-3,
cosine schedule; base model FROZEN during head training; Hydra++ trained
for ~10x longer — scaled here via HeadConfig.epochs_scale).
"""

import functools
import math
import time
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, HeadConfig, NUM_DRAFT_HEADS
from . import model as M
from . import heads as H

# ---------------------------------------------------------------------------
# AdamW + cosine schedule (hand-rolled)
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": zeros, "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1 ** tf
    bc2 = 1.0 - b2 ** tf
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * ((m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps) + wd * p),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total_steps, peak=1e-3, warmup_frac=0.05, floor=1e-5):
    warmup = max(1, int(total_steps * warmup_frac))
    warm = peak * jnp.minimum(step / warmup, 1.0)
    prog = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


# ---------------------------------------------------------------------------
# Data batching
# ---------------------------------------------------------------------------


def batch_iter(ids: np.ndarray, batch: int, seq: int, seed: int) -> Iterator[np.ndarray]:
    """Random contiguous windows over the encoded corpus, forever."""
    rng = np.random.default_rng(seed)
    n = len(ids) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([ids[s:s + seq] for s in starts]).astype(np.int32)


# ---------------------------------------------------------------------------
# Base LM training
# ---------------------------------------------------------------------------


def train_base(cfg: ModelConfig, ids: np.ndarray, steps: int, batch: int = 8,
               seq: int = 96, seed: int = 0, log_every: int = 25):
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        def loss_fn(p):
            return M.lm_loss(cfg, p, tokens, jnp.ones_like(tokens, jnp.float32))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    it = batch_iter(ids, batch, seq, seed)
    log = []
    t0 = time.time()
    for s in range(steps):
        lr = cosine_lr(jnp.asarray(s, jnp.float32), steps)
        params, opt, loss = step_fn(params, opt, jnp.asarray(next(it)), lr)
        if s % log_every == 0 or s == steps - 1:
            loss_v = float(loss)
            log.append({"step": s, "loss": round(loss_v, 4),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"  [base-{cfg.name}] step {s:4d} loss {loss_v:.4f}", flush=True)
    return params, log


# ---------------------------------------------------------------------------
# Draft-head training (frozen base)
# ---------------------------------------------------------------------------


def head_loss(cfg: ModelConfig, hc: HeadConfig, base_params, head_params,
              tokens, noise_key):
    """Teacher-forced loss over every position of a batch (paper App. A.1).

    For position p, head i predicts token x_{p+1+i}:
      - 'ntp' objective: cross-entropy against the corpus token;
      - 'teacher': cross-entropy against the base model's distribution at
        position p+i (self-distillation, Zhou et al. 2024).
    Hydra heads are teacher-forced on the TRUE tokens x_{p+1..p+i}.
    """
    b, s = tokens.shape
    base_logits, hidden = M.train_forward(cfg, base_params, tokens, return_hidden=True)
    base_logits = jax.lax.stop_gradient(base_logits)
    hidden = jax.lax.stop_gradient(hidden)

    if hc.noise_alpha > 0.0:
        # NEFT-style noise on the base hidden states (App. A.1; Jain et al.).
        noise = jax.random.uniform(noise_key, hidden.shape, hidden.dtype, -1.0, 1.0)
        hidden = hidden + noise * (hc.noise_alpha / math.sqrt(s * cfg.d_model))

    tok_emb = jax.lax.stop_gradient(base_params["tok_emb"])

    if hc.kind == "eagle":
        h_prev = jnp.concatenate(
            [jnp.zeros((b, 1, cfg.d_model), hidden.dtype), hidden[:, :-1]], axis=1)
        fused = H.eagle_fuse(head_params, tok_emb, tokens, h_prev)
        out, _ = H.decoder_layer_full(cfg, head_params, "eg.", fused,
                                      jnp.full((b,), s, jnp.int32))
        # Token loss: predict x_{p+1} via the frozen base LM head...
        pred_logits = M.rmsnorm(out, jax.lax.stop_gradient(base_params["final_norm"])) \
            @ jax.lax.stop_gradient(base_params["lm_head"])
        logp = jax.nn.log_softmax(pred_logits[:, :-1], axis=-1)
        if hc.objective == "teacher":
            tgt_p = jax.nn.softmax(base_logits[:, :-1], axis=-1)
            ce = -(tgt_p * logp).sum(-1).mean()
        else:
            ce = -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1).mean()
        # ...plus hidden-state regression f̂_p ≈ h_p (Li et al. 2024).
        reg = jnp.abs(out - hidden).mean()
        return ce + 0.5 * reg

    h_star = hidden
    if hc.prefix_attn:
        h_star, _ = H.decoder_layer_full(cfg, head_params, "prefix.", hidden,
                                         jnp.full((b,), s, jnp.int32))

    emb_all = tok_emb[tokens]                      # [B, S, D]
    total, denom = 0.0, 0
    for i in range(1, NUM_DRAFT_HEADS + 1):
        valid = s - 1 - i                          # positions p = 0..valid-1
        h_in = h_star[:, :valid]
        if hc.kind == "medusa":
            x_in = h_in
        else:
            path = [emb_all[:, j:j + valid] for j in range(1, i + 1)]
            x_in = jnp.concatenate([h_in] + path, axis=-1)
        logits = H.mlp_head_forward(head_params, hc, i, x_in)   # [B, valid, V]
        logp = jax.nn.log_softmax(logits, axis=-1)
        if hc.objective == "teacher":
            tgt_p = jax.nn.softmax(base_logits[:, i:i + valid], axis=-1)
            ce = -(tgt_p * logp).sum(-1).mean()
        else:
            tgt = tokens[:, i + 1:i + 1 + valid]
            ce = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).mean()
        total = total + ce
        denom += 1
    return total / denom


def train_heads(cfg: ModelConfig, hc: HeadConfig, base_params, ids: np.ndarray,
                steps: int, batch: int = 8, seq: int = 96, seed: int = 1,
                log_every: int = 25):
    steps = max(10, int(steps * hc.epochs_scale))
    head_params = H.init_head_params(cfg, hc, jax.random.PRNGKey(seed + hash(hc.name) % 1000))
    opt = adamw_init(head_params)

    @jax.jit
    def step_fn(head_params, opt, tokens, lr, key):
        def loss_fn(hp):
            return head_loss(cfg, hc, base_params, hp, tokens, key)
        loss, grads = jax.value_and_grad(loss_fn)(head_params)
        head_params, opt = adamw_update(head_params, grads, opt, lr)
        return head_params, opt, loss

    it = batch_iter(ids, batch, seq, seed + 77)
    key = jax.random.PRNGKey(seed + 13)
    log = []
    t0 = time.time()
    for s in range(steps):
        key, sub = jax.random.split(key)
        lr = cosine_lr(jnp.asarray(s, jnp.float32), steps)
        head_params, opt, loss = step_fn(head_params, opt, jnp.asarray(next(it)), lr, sub)
        if s % log_every == 0 or s == steps - 1:
            loss_v = float(loss)
            log.append({"step": s, "loss": round(loss_v, 4),
                        "elapsed_s": round(time.time() - t0, 1)})
            print(f"  [{cfg.name}/{hc.name}] step {s:4d} loss {loss_v:.4f}", flush=True)
    return head_params, log
