"""L2 — base transformer (LLaMA-style) and its AOT entry points.

Entry points lowered to HLO artifacts (see aot.py):
  prefill        — process a padded prompt, build the KV cache
  verify         — score a packed candidate tree (T=1 doubles as AR decode);
                   the attention uses the Pallas tree-attention kernel (L1)
  commit         — scatter accepted tree KVs into the cache (device-side)
  train_forward  — full causal LM forward (build-time training only)

Weights are runtime inputs (never baked as HLO constants): every entry point
takes `params` as a flat, name-ordered list — the order is recorded in
artifacts/manifest.json and mirrored by rust/src/runtime/weights.rs.
"""

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, ACCEPT_MAX
from .kernels.ref import tree_attention_ref, swiglu_ref, NEG_INF
from .kernels.tree_attention import tree_attention

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Flat dict of name -> array. Names are sorted for the AOT arg order."""
    params: Dict[str, jnp.ndarray] = {}
    k_iter = iter(jax.random.split(key, 4 + 16 * cfg.n_layers))

    def dense(shape, scale=None):
        fan_in = shape[0]
        scale = scale if scale is not None else fan_in ** -0.5
        return jax.random.normal(next(k_iter), shape, jnp.float32) * scale

    params["tok_emb"] = dense((cfg.vocab, cfg.d_model), 0.02)
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        params[p + "attn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[p + "wq"] = dense((cfg.d_model, cfg.n_heads * cfg.head_dim))
        params[p + "wk"] = dense((cfg.d_model, cfg.kv_dim))
        params[p + "wv"] = dense((cfg.d_model, cfg.kv_dim))
        params[p + "wo"] = dense((cfg.n_heads * cfg.head_dim, cfg.d_model))
        params[p + "ffn_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        params[p + "w1"] = dense((cfg.d_model, cfg.d_ffn))
        params[p + "w2"] = dense((cfg.d_ffn, cfg.d_model))
        params[p + "w3"] = dense((cfg.d_model, cfg.d_ffn))
    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    params["lm_head"] = dense((cfg.d_model, cfg.vocab), 0.02)
    return params


def param_names(cfg: ModelConfig) -> List[str]:
    return sorted(init_params(cfg, jax.random.PRNGKey(0)).keys())


def params_to_list(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[k] for k in sorted(params.keys())]


def params_from_list(names: List[str], arrays) -> Dict[str, jnp.ndarray]:
    return dict(zip(sorted(names), arrays))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: [..., T] (broadcast over heads)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions [..., T] -> angles [..., T, 1, half] (broadcast over heads)
    ang = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(cfg: ModelConfig, p: Dict[str, jnp.ndarray], i: int, x: jnp.ndarray):
    pre = f"layer{i:02d}."
    xn = rmsnorm(x, p[pre + "attn_norm"])
    q = xn @ p[pre + "wq"]
    k = xn @ p[pre + "wk"]
    v = xn @ p[pre + "wv"]
    return xn, q, k, v


def _ffn(cfg: ModelConfig, p: Dict[str, jnp.ndarray], i: int, x: jnp.ndarray):
    pre = f"layer{i:02d}."
    xn = rmsnorm(x, p[pre + "ffn_norm"])
    return swiglu_ref(xn, p[pre + "w1"], p[pre + "w2"], p[pre + "w3"])


# ---------------------------------------------------------------------------
# Training / prefill forward (plain jnp — cold path)
# ---------------------------------------------------------------------------


def train_forward(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
                  return_hidden: bool = False):
    """Full causal forward. tokens: [B, S] -> logits [B, S, V]."""
    b, s = tokens.shape
    x = p["tok_emb"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    causal = jnp.tril(jnp.ones((s, s), bool))
    for i in range(cfg.n_layers):
        _, q, k, v = _qkv(cfg, p, i, x)
        q = rope(q.reshape(b, s, cfg.n_heads, cfg.head_dim), positions, cfg.rope_theta)
        k = rope(k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim), positions, cfg.rope_theta)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        groups = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(k, groups, axis=2)
        vv = jnp.repeat(v, groups, axis=2)
        logits = jnp.einsum("bthd,bshd->bhts", q, kk) / (cfg.head_dim ** 0.5)
        logits = jnp.where(causal[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", probs, vv).reshape(b, s, -1)
        x = x + attn @ p[f"layer{i:02d}.wo"]
        x = x + _ffn(cfg, p, i, x)
    h = rmsnorm(x, p["final_norm"])
    logits = h @ p["lm_head"]
    if return_hidden:
        return logits, x  # pre-final-norm hidden (what draft heads consume)
    return logits


def prefill(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
            length: jnp.ndarray):
    """tokens: [B, Smax] (padded), length: [B] i32.

    Returns (last_hidden [B, D], last_logits [B, V], kv [B, L, 2, Smax, KVD]).
    last_* are taken at index length-1. kv rows at padded positions are
    whatever the forward computed there — they are never attended to because
    verify masks keys by cur_len.
    """
    b, s = tokens.shape
    x = p["tok_emb"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    valid = positions < length[:, None]
    causal = jnp.tril(jnp.ones((s, s), bool))[None] & valid[:, None, :]
    kv_all = []
    for i in range(cfg.n_layers):
        _, q, k, v = _qkv(cfg, p, i, x)
        q = rope(q.reshape(b, s, cfg.n_heads, cfg.head_dim), positions, cfg.rope_theta)
        k = rope(k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim), positions, cfg.rope_theta)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        kv_all.append(jnp.stack([k.reshape(b, s, -1), v.reshape(b, s, -1)], axis=1))
        groups = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(k, groups, axis=2)
        vv = jnp.repeat(v, groups, axis=2)
        logits = jnp.einsum("bthd,bshd->bhts", q, kk) / (cfg.head_dim ** 0.5)
        logits = jnp.where(causal[:, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", probs, vv).reshape(b, s, -1)
        x = x + attn @ p[f"layer{i:02d}.wo"]
        x = x + _ffn(cfg, p, i, x)
    kv = jnp.stack(kv_all, axis=1)  # [B, L, 2, S, KVD]
    idx = jnp.clip(length - 1, 0, s - 1)
    last_x = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]  # [B, D]
    last_logits = rmsnorm(last_x, p["final_norm"]) @ p["lm_head"]
    return last_x, last_logits, kv, x


def prefill_with_hidden(cfg: ModelConfig, p: Dict[str, jnp.ndarray],
                        tokens: jnp.ndarray, length: jnp.ndarray):
    """AOT prefill entry: (last_hidden [B,D], last_logits [B,V],
    kv [B,L,2,S,KVD], hidden_seq [B,S,D]). hidden_seq feeds the
    prefix-attention / EAGLE prefills (device-side chain, no host copy)."""
    return prefill(cfg, p, tokens, length)


# ---------------------------------------------------------------------------
# Verify (hot path — Pallas tree-attention)
# ---------------------------------------------------------------------------


def verify(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
           positions: jnp.ndarray, cur_len: jnp.ndarray, anc_mask: jnp.ndarray,
           kv: jnp.ndarray, use_pallas: bool = True):
    """Score a packed candidate tree in one forward pass.

    tokens/positions: [B, T]; cur_len: [B]; anc_mask: [B, T, T] (i32 0/1,
    ancestor-or-self); kv: [B, L, 2, Smax, KVD].
    Returns (logits [B, T, V], hidden [B, T, D], tree_kv [B, L, 2, T, KVD]).
    """
    b, t = tokens.shape
    s = kv.shape[3]
    x = p["tok_emb"][tokens]
    tree_kv_all = []
    cur_len_b1 = cur_len.reshape(b, 1).astype(jnp.int32)
    for i in range(cfg.n_layers):
        _, q, k, v = _qkv(cfg, p, i, x)
        q = rope(q.reshape(b, t, cfg.n_heads, cfg.head_dim), positions, cfg.rope_theta)
        k = rope(k.reshape(b, t, cfg.n_kv_heads, cfg.head_dim), positions, cfg.rope_theta)
        v = v.reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
        tree_kv_all.append(jnp.stack([k.reshape(b, t, -1), v.reshape(b, t, -1)], axis=1))
        cache_k = kv[:, i, 0].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        cache_v = kv[:, i, 1].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        if use_pallas:
            attn = tree_attention(
                q.transpose(0, 2, 1, 3),            # [B, H, T, hd]
                cache_k.transpose(0, 2, 1, 3),      # [B, KVH, S, hd]
                cache_v.transpose(0, 2, 1, 3),
                k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3),
                cur_len_b1,
                anc_mask,
            ).transpose(0, 2, 1, 3)                 # [B, T, H, hd]
        else:
            attn = jax.vmap(tree_attention_ref)(
                q, cache_k, cache_v, k, v, cur_len.astype(jnp.int32), anc_mask
            )
        attn = attn.reshape(b, t, -1)
        x = x + attn @ p[f"layer{i:02d}.wo"]
        x = x + _ffn(cfg, p, i, x)
    tree_kv = jnp.stack(tree_kv_all, axis=1)  # [B, L, 2, T, KVD]
    logits = rmsnorm(x, p["final_norm"]) @ p["lm_head"]
    return logits, x, tree_kv


# ---------------------------------------------------------------------------
# Commit (device-side cache scatter)
# ---------------------------------------------------------------------------


def commit(kv: jnp.ndarray, tree_kv: jnp.ndarray, hidden: jnp.ndarray,
           accept_idx: jnp.ndarray, accept_len: jnp.ndarray, cur_len: jnp.ndarray):
    """Write accepted tree-node KVs into the cache; gather their hiddens.

    kv: [B, L, 2, S, KVD]; tree_kv: [B, L, 2, T, KVD]; hidden: [B, T, D];
    accept_idx: [B, A] (tree-node indices, root-first path, padded with 0);
    accept_len: [B] (1..A); cur_len: [B].
    Returns (kv', gathered hidden [B, A, D]). Row j of the accepted path
    lands at cache position cur_len + j for j < accept_len.
    """
    b, l, _, s, kvd = kv.shape
    a = accept_idx.shape[1]
    pos_grid = jnp.arange(s, dtype=jnp.int32)                       # [S]
    for j in range(a):
        rows = jnp.take_along_axis(
            tree_kv, accept_idx[:, j][:, None, None, None, None], axis=3
        )                                                           # [B, L, 2, 1, KVD]
        dest = cur_len + j                                          # [B]
        write = (j < accept_len)                                    # [B]
        sel = (pos_grid[None] == dest[:, None]) & write[:, None]    # [B, S]
        sel = sel[:, None, None, :, None]
        kv = jnp.where(sel, rows, kv)
    gathered = jnp.take_along_axis(hidden, accept_idx[..., None], axis=1)  # [B, A, D]
    return kv, gathered


def commit_entry(kv, tree_kv, hidden, accept_idx, accept_len, cur_len):
    return commit(kv, tree_kv, hidden, accept_idx, accept_len, cur_len)


# ---------------------------------------------------------------------------
# Losses (build-time training)
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens: jnp.ndarray,
            mask: jnp.ndarray) -> jnp.ndarray:
    """Next-token CE; mask: [B, S] 1 where the *target* position counts."""
    logits = train_forward(cfg, p, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
