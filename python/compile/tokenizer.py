"""BPE-lite tokenizer: 256 byte tokens + greedily learned pair merges.

Trained once over the synthetic corpus during `make artifacts`; the merge
table is exported to artifacts/tokenizer.json and re-applied by the Rust
tokenizer (rust/src/tokenizer/) with identical semantics — encode parity is
asserted by an integration test over shared vectors
(artifacts/tokenizer_vectors.json).
"""

import json
from collections import Counter
from typing import Dict, List, Tuple

N_BYTE_TOKENS = 256


def train_merges(text: str, n_merges: int) -> List[Tuple[int, int]]:
    """Greedy BPE on byte ids. Merge rank == creation order (like GPT-2)."""
    ids = list(text.encode("utf-8"))
    merges: List[Tuple[int, int]] = []
    for step in range(n_merges):
        counts = Counter(zip(ids, ids[1:]))
        if not counts:
            break
        pair, freq = counts.most_common(1)[0]
        if freq < 2:
            break
        new_id = N_BYTE_TOKENS + step
        merges.append(pair)
        ids = _apply_merge(ids, pair, new_id)
    return merges


def _apply_merge(ids: List[int], pair: Tuple[int, int], new_id: int) -> List[int]:
    out: List[int] = []
    i, n = 0, len(ids)
    while i < n:
        if i + 1 < n and ids[i] == pair[0] and ids[i + 1] == pair[1]:
            out.append(new_id)
            i += 2
        else:
            out.append(ids[i])
            i += 1
    return out


class Tokenizer:
    def __init__(self, merges: List[Tuple[int, int]]):
        self.merges = [tuple(m) for m in merges]
        self.ranks: Dict[Tuple[int, int], int] = {
            tuple(p): i for i, p in enumerate(self.merges)
        }
        self.vocab_size = N_BYTE_TOKENS + len(self.merges)

    def encode(self, text: str) -> List[int]:
        ids = list(text.encode("utf-8"))
        # Repeatedly apply the lowest-rank (earliest-learned) applicable
        # merge — standard BPE inference, mirrored exactly in Rust.
        while len(ids) >= 2:
            best_rank, best_pos = None, -1
            for i in range(len(ids) - 1):
                r = self.ranks.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_pos = r, i
            if best_rank is None:
                break
            new_id = N_BYTE_TOKENS + best_rank
            pair = self.merges[best_rank]
            ids = _apply_merge(ids, pair, new_id)
        return ids

    def decode(self, ids: List[int]) -> str:
        data = bytearray()
        for tid in ids:
            data.extend(self._expand(tid))
        return data.decode("utf-8", errors="replace")

    def _expand(self, tid: int) -> bytes:
        if tid < N_BYTE_TOKENS:
            return bytes([tid])
        a, b = self.merges[tid - N_BYTE_TOKENS]
        return self._expand(a) + self._expand(b)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {"n_byte_tokens": N_BYTE_TOKENS, "merges": [list(m) for m in self.merges]},
                f,
            )

    @classmethod
    def load(cls, path: str) -> "Tokenizer":
        with open(path) as f:
            obj = json.load(f)
        return cls([tuple(m) for m in obj["merges"]])

    def encode_corpus(self, text: str):
        """Fast numpy bulk encoder for the training corpus.

        Applies each merge exhaustively in rank order — equivalent to the
        lowest-rank-first inference in `encode` (both always prefer the
        lowest-rank applicable merge, greedy left-to-right), but O(n) per
        merge in C instead of a Python scan per step.
        """
        import numpy as np

        ids = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)
        for rank, (a, b) in enumerate(self.merges):
            new_id = N_BYTE_TOKENS + rank
            match = (ids[:-1] == a) & (ids[1:] == b)
            idx = np.flatnonzero(match)
            if idx.size == 0:
                continue
            # Drop overlapping consecutive matches (greedy left-to-right).
            keep = [int(idx[0])]
            for t in idx[1:]:
                if t != keep[-1] + 1:
                    keep.append(int(t))
            keep_arr = np.array(keep)
            ids[keep_arr] = new_id
            ids = np.delete(ids, keep_arr + 1)
        return ids
