"""AOT pipeline: corpus → tokenizer → training → HLO-text artifacts.

Runs ONCE via `make artifacts`; Python never touches the request path.
Outputs in artifacts/:
  tokenizer.json            BPE merge table (applied identically in Rust)
  tokenizer_vectors.json    encode parity vectors for the Rust tokenizer test
  prompts.json              held-out eval prompts (MT-Bench-sim / SpecBench-sim)
  corpus_sample.json        tokenized corpus slices (Rust tree-search input)
  weights_base_{z}.bin      base weights      (custom HTB1 tensor binary)
  weights_heads_{z}_{v}.bin head weights per variant
  train_logs.json           loss curves for every training run
  *.hlo.txt                 one HLO-text program per (entry point, shape bucket)
  manifest.json             executable/arg/weight-order index for the Rust side

HLO TEXT is the interchange format — NOT serialized HloModuleProto: jax
>= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import struct
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import (SIZES, ModelConfig, HeadConfig, head_variants_for_size,
                     VOCAB_SIZE, SEQ_MAX, NUM_DRAFT_HEADS, ACCEPT_MAX,
                     TREE_BUCKETS)
from . import data, tokenizer as tok_mod, model as M, heads as H, train as T

DT = {"f32": jnp.float32, "i32": jnp.int32}


# ---------------------------------------------------------------------------
# HLO text lowering (see module docstring for why text)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# HTB1 tensor binary (parsed by rust/src/util/tensors.rs)
# ---------------------------------------------------------------------------


def write_tensors(path: str, tensors: Dict[str, np.ndarray]) -> None:
    entries, payload = [], b""
    for name in sorted(tensors.keys()):
        arr = np.ascontiguousarray(tensors[name])
        assert arr.dtype in (np.float32, np.int32), arr.dtype
        dtype = "f32" if arr.dtype == np.float32 else "i32"
        entries.append({"name": name, "dtype": dtype, "shape": list(arr.shape),
                        "offset": len(payload), "nbytes": arr.nbytes})
        payload += arr.tobytes()
    header = json.dumps({"tensors": entries}).encode()
    with open(path, "wb") as f:
        f.write(b"HTB1")
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(payload)


# ---------------------------------------------------------------------------
# Executable builder
# ---------------------------------------------------------------------------


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest_exes: Dict[str, dict] = {}

    def emit(self, name: str, fn, dyn_specs: List[tuple], weight_args: List[tuple],
             weight_arrays: List[jnp.ndarray]):
        """Lower fn(*dyn, *weights) and record the arg contract.

        dyn_specs:   [(arg_name, shape, dtype_str), ...]
        weight_args: [(kind, tensor_name), ...] with kind in {base, head}
        """
        t0 = time.time()
        dyn_structs = [jax.ShapeDtypeStruct(s, DT[d]) for (_, s, d) in dyn_specs]
        w_structs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in weight_arrays]
        lowered = jax.jit(fn).lower(*dyn_structs, *w_structs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *dyn_structs, *w_structs)
        out_specs = [{"shape": list(o.shape),
                      "dtype": "i32" if str(o.dtype).startswith("int") else "f32"}
                     for o in jax.tree_util.tree_leaves(outs)]
        self.manifest_exes[name] = {
            "file": fname,
            "args": ([{"kind": "dyn", "name": n, "shape": list(s), "dtype": d}
                      for (n, s, d) in dyn_specs]
                     + [{"kind": k, "name": n} for (k, n) in weight_args]),
            "outputs": out_specs,
        }
        print(f"  lowered {name} ({len(text) // 1024} KiB, {time.time() - t0:.1f}s)", flush=True)

    def alias(self, name: str, target: str):
        """Record `name` as an alias of an already-emitted executable.

        The manifest entry is copied verbatim (same .hlo.txt file, same
        args/outputs), so the Rust side sees a fully specified executable
        under the alias at zero extra lowering cost. Used for the
        `*_masked_*` capability aliases: because the ancestor mask is a
        runtime input tensor, a verify/commit executable serves ANY tree
        topology up to its node capacity (padding rows are self-only and
        inert), and the alias advertises that contract under a
        bucket-free name that `model::Manifest::masked_tree_cap` probes.
        """
        self.manifest_exes[name] = dict(self.manifest_exes[target])
        print(f"  alias   {name} -> {target}", flush=True)


def base_weight_args(cfg: ModelConfig, base_params):
    names = sorted(base_params.keys())
    return [("base", n) for n in names], [base_params[n] for n in names]


def head_weight_args(head_params, subset=None):
    names = sorted(head_params.keys())
    if subset is not None:
        names = [n for n in names if subset(n)]
    return [("head", n) for n in names], [head_params[n] for n in names]


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="tiny build for CI: size s only, few train steps")
    args = ap.parse_args()
    fast = args.fast or os.environ.get("HYDRA_FAST") == "1"

    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    t_start = time.time()

    sizes = ["s"] if fast else os.environ.get("HYDRA_SIZES", "s,m,l").split(",")
    base_steps = int(os.environ.get("HYDRA_BASE_STEPS", "40" if fast else "360"))
    head_steps = int(os.environ.get("HYDRA_HEAD_STEPS", "25" if fast else "220"))

    # ---- corpus + tokenizer -------------------------------------------------
    print("== corpus + tokenizer ==", flush=True)
    corpus = data.gen_corpus(n_examples=1200 if fast else 9000)
    merges = tok_mod.train_merges(corpus[:120_000], VOCAB_SIZE - tok_mod.N_BYTE_TOKENS)
    tok = tok_mod.Tokenizer(merges)
    tok.save(os.path.join(out_dir, "tokenizer.json"))
    ids = tok.encode_corpus(corpus)
    print(f"  corpus {len(corpus)} chars -> {len(ids)} tokens "
          f"(vocab {tok.vocab_size})", flush=True)

    vectors = []
    probe_rng = np.random.default_rng(7)
    for _ in range(60):
        a = int(probe_rng.integers(0, max(1, len(corpus) - 80)))
        text = corpus[a:a + int(probe_rng.integers(5, 80))]
        vectors.append({"text": text, "ids": [int(x) for x in tok.encode(text)]})
    with open(os.path.join(out_dir, "tokenizer_vectors.json"), "w") as f:
        json.dump(vectors, f)

    prompts = data.gen_eval_prompts(per_category=8 if fast else 24)
    data.write_prompts(os.path.join(out_dir, "prompts.json"), prompts)

    # Tokenized corpus slices for the Rust tree-search simulator (paper §4
    # uses a 100-prompt Alpaca subset; we use held-out corpus windows).
    search_rng = np.random.default_rng(21)
    slices = []
    for _ in range(100):
        a = int(search_rng.integers(0, len(ids) - 257))
        slices.append([int(x) for x in ids[a:a + 256]])
    with open(os.path.join(out_dir, "corpus_sample.json"), "w") as f:
        json.dump(slices, f)

    # ---- training -----------------------------------------------------------
    train_logs: Dict[str, list] = {}
    base_params_by_size: Dict[str, dict] = {}
    head_params_by: Dict[str, Dict[str, dict]] = {}
    for z in sizes:
        cfg = SIZES[z]
        print(f"== train base-{z} ({cfg.param_count()/1e6:.2f}M params) ==", flush=True)
        bp, log = T.train_base(cfg, ids, steps=base_steps, seed=42)
        base_params_by_size[z] = bp
        train_logs[f"base_{z}"] = log
        write_tensors(os.path.join(out_dir, f"weights_base_{z}.bin"),
                      {k: np.asarray(v) for k, v in bp.items()})
        head_params_by[z] = {}
        for hc in head_variants_for_size(z):
            if fast and hc.name not in ("medusa", "hydra", "hydra_pp", "eagle"):
                continue
            print(f"== train heads {z}/{hc.name} ==", flush=True)
            hp, hlog = T.train_heads(cfg, hc, bp, ids, steps=head_steps)
            head_params_by[z][hc.name] = hp
            train_logs[f"heads_{z}_{hc.name}"] = hlog
            write_tensors(os.path.join(out_dir, f"weights_heads_{z}_{hc.name}.bin"),
                          {k: np.asarray(v) for k, v in hp.items()})
    with open(os.path.join(out_dir, "train_logs.json"), "w") as f:
        json.dump(train_logs, f, indent=1)

    # ---- AOT lowering -------------------------------------------------------
    print("== AOT lowering ==", flush=True)
    b = Builder(out_dir)
    S, V, A, K = SEQ_MAX, VOCAB_SIZE, ACCEPT_MAX, NUM_DRAFT_HEADS
    tree_buckets = [1, 8, 16] if fast else TREE_BUCKETS
    batch_buckets = {z: ([1, 2, 4, 8] if (z == "s" and not fast) else [1])
                     for z in sizes}
    hydra_m_buckets = {z: ([16, 64, 128] if z == "s" and not fast else [16, 64])
                       for z in sizes}
    eagle_n_buckets = [16, 64]

    for z in sizes:
        cfg = SIZES[z]
        bp = base_params_by_size[z]
        bw_args, bw_arrays = base_weight_args(cfg, bp)
        D, L, KVD = cfg.d_model, cfg.n_layers, cfg.kv_dim
        names = sorted(bp.keys())

        def with_base(fn):
            def wrapped(*args):
                dyn, w = args[:-len(names)], args[-len(names):]
                return fn(M.params_from_list(names, w), *dyn)
            return wrapped

        for B in batch_buckets[z]:
            b.emit(
                f"prefill_{z}_b{B}",
                with_base(lambda p, tokens, length:
                          _prefill_full(cfg, p, tokens, length)),
                [("tokens", (B, S), "i32"), ("length", (B,), "i32")],
                bw_args, bw_arrays)
            for TT in tree_buckets:
                b.emit(
                    f"verify_{z}_b{B}_t{TT}",
                    with_base(lambda p, tokens, positions, cur_len, anc, kv:
                              M.verify(cfg, p, tokens, positions, cur_len, anc, kv)),
                    [("tokens", (B, TT), "i32"), ("positions", (B, TT), "i32"),
                     ("cur_len", (B,), "i32"), ("anc_mask", (B, TT, TT), "i32"),
                     ("kv", (B, L, 2, S, KVD), "f32")],
                    bw_args, bw_arrays)
                b.emit(
                    f"commit_{z}_b{B}_t{TT}",
                    M.commit_entry,
                    [("kv", (B, L, 2, S, KVD), "f32"),
                     ("tree_kv", (B, L, 2, TT, KVD), "f32"),
                     ("hidden", (B, TT, D), "f32"),
                     ("accept_idx", (B, A), "i32"),
                     ("accept_len", (B,), "i32"), ("cur_len", (B,), "i32")],
                    [], [])
            # Masked-capability aliases: the widest tree bucket, with the
            # ancestor mask as a runtime input, runs any topology of up to
            # max(tree_buckets) nodes in one call — no t{N} ladder. The
            # Rust engine probes these names to certify mask-parameterized
            # verification and then pins a single bucket per engine.
            TM = max(tree_buckets)
            b.alias(f"verify_masked_{z}_b{B}", f"verify_{z}_b{B}_t{TM}")
            b.alias(f"commit_masked_{z}_b{B}", f"commit_{z}_b{B}_t{TM}")

        # -- draft executables (head weights are runtime args, so one
        #    executable serves every variant with the same architecture) --
        trained = head_params_by[z]
        archs = {}   # (kind, mlp_layers, prefix) -> example params
        for hc in head_variants_for_size(z):
            if hc.name in trained:
                archs[(hc.kind, hc.mlp_layers, hc.prefix_attn)] = (hc, trained[hc.name])

        for (kind, ml, pref), (hc, hp) in archs.items():
            if kind == "medusa":
                hw_args, hw_arrays = head_weight_args(hp)
                b.emit(f"medusa_draft_{z}",
                       lambda h, *w, hc=hc: H.medusa_draft(
                           dict(zip([n for _, n in hw_args], w)), hc, h),
                       [("h", (8, D), "f32")], hw_args, hw_arrays)
            elif kind == "hydra":
                for i in range(1, K + 1):
                    subset = (lambda n, i=i: n.startswith(f"head{i}."))
                    hw_args, hw_arrays = head_weight_args(hp, subset)
                    arg_names = [n for _, n in hw_args]
                    for MM in hydra_m_buckets[z]:
                        b.emit(
                            f"hydra_draft_{z}_L{ml}_d{i}_m{MM}",
                            lambda h, path, emb, *w, hc=hc, i=i, an=tuple(arg_names):
                                H.hydra_draft(dict(zip(an, w)), hc, i, emb, h, path),
                            [("h", (MM, D), "f32"), ("path", (MM, i), "i32")],
                            [("base", "tok_emb")] + hw_args,
                            [bp["tok_emb"]] + hw_arrays)
                if pref:
                    subset = (lambda n: n.startswith("prefix."))
                    hw_args, hw_arrays = head_weight_args(hp, subset)
                    an = [n for _, n in hw_args]
                    for B in batch_buckets[z]:
                        b.emit(f"prefix_prefill_{z}_b{B}_L{ml}",
                               lambda hseq, length, *w, an=tuple(an):
                                   H.prefix_prefill(cfg, dict(zip(an, w)), hseq, length),
                               [("hidden_seq", (B, S, D), "f32"), ("length", (B,), "i32")],
                               hw_args, hw_arrays)
                        b.emit(f"prefix_step_{z}_b{B}_L{ml}",
                               lambda nh, count, cur_len, pkv, *w, an=tuple(an):
                                   H.prefix_step(cfg, dict(zip(an, w)), nh, count, cur_len, pkv),
                               [("new_hidden", (B, A, D), "f32"), ("count", (B,), "i32"),
                                ("cur_len", (B,), "i32"), ("pkv", (B, 2, S, KVD), "f32")],
                               hw_args, hw_arrays)
            elif kind == "eagle":
                hw_args, hw_arrays = head_weight_args(hp)
                an = [n for _, n in hw_args]
                B = 1
                b.emit(f"eagle_prefill_{z}_b{B}",
                       lambda tokens, hseq, length, emb, *w, an=tuple(an):
                           H.eagle_prefill(cfg, dict(zip(an, w)), emb, tokens, hseq, length),
                       [("tokens", (B, S), "i32"), ("hidden_seq", (B, S, D), "f32"),
                        ("length", (B,), "i32")],
                       [("base", "tok_emb")] + hw_args, [bp["tok_emb"]] + hw_arrays)
                for N in eagle_n_buckets:
                    b.emit(f"eagle_step_{z}_b{B}_n{N}",
                           lambda tokens, hpar, pos, cur_len, ekv, emb, fn_, lm, *w, an=tuple(an):
                               H.eagle_step(cfg, dict(zip(an, w)), emb, lm, fn_,
                                            tokens, hpar, pos, cur_len, ekv),
                           [("tokens", (B, N), "i32"), ("h_parent", (B, N, D), "f32"),
                            ("pos", (B, N), "i32"), ("cur_len", (B,), "i32"),
                            ("ekv", (B, 2, S, KVD), "f32")],
                           [("base", "tok_emb"), ("base", "final_norm"), ("base", "lm_head")] + hw_args,
                           [bp["tok_emb"], bp["final_norm"], bp["lm_head"]] + hw_arrays)
                b.emit(f"eagle_extend_{z}_b{B}",
                       lambda tokens, hpar, count, cur_len, ekv, emb, *w, an=tuple(an):
                           H.eagle_extend(cfg, dict(zip(an, w)), emb, tokens, hpar,
                                          count, cur_len, ekv),
                       [("tokens", (B, A), "i32"), ("h_parent", (B, A, D), "f32"),
                        ("count", (B,), "i32"), ("cur_len", (B,), "i32"),
                        ("ekv", (B, 2, S, KVD), "f32")],
                       [("base", "tok_emb")] + hw_args, [bp["tok_emb"]] + hw_arrays)

    # ---- manifest -----------------------------------------------------------
    manifest = {
        "version": 1,
        "vocab": V, "seq_max": S, "accept_max": A, "num_heads": K,
        "tree_buckets": tree_buckets,
        "batch_buckets": batch_buckets,
        "hydra_m_buckets": hydra_m_buckets,
        "eagle_n_buckets": eagle_n_buckets,
        "sizes": {z: {"d_model": SIZES[z].d_model, "n_layers": SIZES[z].n_layers,
                      "n_heads": SIZES[z].n_heads, "n_kv_heads": SIZES[z].n_kv_heads,
                      "d_ffn": SIZES[z].d_ffn, "kv_dim": SIZES[z].kv_dim,
                      "params": SIZES[z].param_count()}
                  for z in sizes},
        "head_variants": {z: [{"name": hc.name, "kind": hc.kind,
                               "mlp_layers": hc.mlp_layers,
                               "prefix_attn": hc.prefix_attn,
                               "objective": hc.objective}
                              for hc in head_variants_for_size(z)
                              if hc.name in head_params_by[z]]
                          for z in sizes},
        "weight_files": {
            **{f"base_{z}": f"weights_base_{z}.bin" for z in sizes},
            **{f"heads_{z}_{v}": f"weights_heads_{z}_{v}.bin"
               for z in sizes for v in head_params_by[z]},
        },
        "executables": b.manifest_exes,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== done: {len(b.manifest_exes)} executables, "
          f"{time.time() - t_start:.0f}s total ==", flush=True)


def _prefill_full(cfg, p, tokens, length):
    """prefill that also returns the full hidden sequence (input for the
    prefix-attention and EAGLE prefills)."""
    b_, s = tokens.shape
    # Reuse train_forward internals via prefill (which computes kv) plus the
    # hidden sequence from train_forward would double compute; instead extend
    # prefill to emit hidden_seq directly.
    return M.prefill_with_hidden(cfg, p, tokens, length)


if __name__ == "__main__":
    main()
