"""Retrain draft heads against existing base weights, rewriting only the
weights_heads_*.bin artifacts (HLO programs take weights as runtime inputs,
so no re-lowering is needed — manifest stays valid).

Usage:  cd python && python -m compile.retrain_heads --steps 500 \
            [--sizes s,m,l] [--variants medusa,hydra,hydra_pp] [--out ../artifacts]

Used to push head training closer to saturation than the initial
`make artifacts` pass (the paper trains to saturation; §5).
"""

import argparse
import json
import os
import struct

import jax.numpy as jnp
import numpy as np

from .config import SIZES, head_variants_for_size
from . import data, tokenizer as tok_mod, train as T
from .aot import write_tensors


def read_tensors(path):
    raw = open(path, "rb").read()
    assert raw[:4] == b"HTB1"
    hlen = struct.unpack("<I", raw[4:8])[0]
    header = json.loads(raw[8:8 + hlen])
    payload = raw[8 + hlen:]
    out = {}
    for e in header["tensors"]:
        dt = np.float32 if e["dtype"] == "f32" else np.int32
        out[e["name"]] = jnp.asarray(
            np.frombuffer(payload[e["offset"]:e["offset"] + e["nbytes"]], dt)
            .reshape(e["shape"]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--sizes", default="s,m,l")
    ap.add_argument("--variants", default="")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)

    tok = tok_mod.Tokenizer.load(os.path.join(out_dir, "tokenizer.json"))
    corpus = data.gen_corpus(n_examples=9000)
    ids = np.asarray(tok.encode_corpus(corpus))
    only = [v for v in args.variants.split(",") if v]

    logs_path = os.path.join(out_dir, "train_logs.json")
    logs = json.load(open(logs_path)) if os.path.exists(logs_path) else {}

    for z in args.sizes.split(","):
        base_file = os.path.join(out_dir, f"weights_base_{z}.bin")
        if not os.path.exists(base_file):
            print(f"skip size {z}: no base weights")
            continue
        bp = read_tensors(base_file)
        cfg = SIZES[z]
        for hc in head_variants_for_size(z):
            if only and hc.name not in only:
                continue
            f = os.path.join(out_dir, f"weights_heads_{z}_{hc.name}.bin")
            if not os.path.exists(f):
                continue  # not part of the original build
            print(f"== retrain {z}/{hc.name} ({args.steps} x{hc.epochs_scale}) ==", flush=True)
            hp, log = T.train_heads(cfg, hc, bp, ids, steps=args.steps, log_every=100)
            write_tensors(f, {k: np.asarray(v) for k, v in hp.items()})
            logs[f"heads_{z}_{hc.name}"] = log
    with open(logs_path, "w") as fh:
        json.dump(logs, fh, indent=1)
    print("done")


if __name__ == "__main__":
    main()
