"""Emit ADDITIONAL AOT executables against an existing artifacts directory,
merging new entries into manifest.json. Lowering needs only shapes (weights
are runtime inputs), so this runs in seconds — no retraining.

Currently emits the §Perf fused entry point:

  verify_commit_{z}_b{B}_t{T}
    1. scatter the PREVIOUS step's accepted tree KVs into the cache
       (no-op rows when accept_len == 0, e.g. the first step);
    2. verify the NEW candidate tree against the updated cache.

One PJRT call and one KV host round-trip per decode step instead of two —
the per-call dispatch + transfer overhead is the dominant cost at this
model scale (EXPERIMENTS.md §Perf)."""

import argparse
import json
import os

import jax

from .config import SIZES, SEQ_MAX, VOCAB_SIZE, ACCEPT_MAX
from . import model as M
from .aot import Builder, DT


def fused_verify_commit(cfg):
    def fn(params, tokens, positions, cur_len, anc_mask, kv,
           prev_tree_kv, prev_hidden, accept_idx, accept_len, commit_base):
        # `gathered` must stay an output: dropping it would leave
        # `prev_hidden` unused and the lowering prunes unused parameters,
        # breaking the manifest arg contract.
        kv2, gathered = M.commit(kv, prev_tree_kv, prev_hidden, accept_idx,
                                 accept_len, commit_base)
        logits, hidden, tree_kv = M.verify(cfg, params, tokens, positions,
                                           cur_len, anc_mask, kv2)
        return logits, hidden, tree_kv, kv2, gathered

    return fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = json.load(open(manifest_path))

    b = Builder(out_dir)
    S, A = manifest["seq_max"], manifest["accept_max"]
    tree_buckets = manifest["tree_buckets"]
    for z, dims in manifest["sizes"].items():
        cfg = SIZES[z]
        D, L, KVD = dims["d_model"], dims["n_layers"], dims["kv_dim"]
        # Weight arg order mirrors aot.py (sorted names).
        names = sorted(M.init_params(cfg, jax.random.PRNGKey(0)).keys())
        shapes = {k: v.shape for k, v in M.init_params(cfg, jax.random.PRNGKey(0)).items()}
        w_args = [("base", n) for n in names]
        w_structs = [jax.ShapeDtypeStruct(shapes[n], DT["f32"]) for n in names]

        fn = fused_verify_commit(cfg)
        for B in manifest["batch_buckets"][z]:
            for T in tree_buckets:
                def wrapped(tokens, positions, cur_len, anc, kv, ptkv, phid,
                            aidx, alen, cbase, *w):
                    return fn(dict(zip(names, w)), tokens, positions, cur_len,
                              anc, kv, ptkv, phid, aidx, alen, cbase)

                b.emit(
                    f"verify_commit_{z}_b{B}_t{T}",
                    wrapped,
                    [("tokens", (B, T), "i32"), ("positions", (B, T), "i32"),
                     ("cur_len", (B,), "i32"), ("anc_mask", (B, T, T), "i32"),
                     ("kv", (B, L, 2, S, KVD), "f32"),
                     ("prev_tree_kv", (B, L, 2, T, KVD), "f32"),
                     ("prev_hidden", (B, T, D), "f32"),
                     ("accept_idx", (B, A), "i32"),
                     ("accept_len", (B,), "i32"), ("commit_base", (B,), "i32")],
                    w_args,
                    w_structs)
            # Masked-capability alias for the fused step (see aot.py):
            # certifies that the widest fused bucket serves any topology
            # via its runtime anc_mask input.
            TM = max(tree_buckets)
            b.alias(f"verify_commit_masked_{z}_b{B}", f"verify_commit_{z}_b{B}_t{TM}")

    manifest["executables"].update(b.manifest_exes)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"merged {len(b.manifest_exes)} executables into manifest")


if __name__ == "__main__":
    main()
