"""Draft-model architectures (L2).

  medusa   — K sequentially-INDEPENDENT residual-MLP heads (Cai et al. 2024):
             head i sees only h_t and predicts the token i+1 steps ahead.
  hydra    — K sequentially-DEPENDENT MLP heads (paper §3): head i sees
             [h_t ; E(x̂_{t+1}) ; … ; E(x̂_{t+i})] (feature-dim concat).
  hydra++  — hydra with 4-layer head MLPs, teacher (self-distillation)
             objective and a prefix-attention decoder layer whose output
             replaces h_t as the draft input state (paper §3.1, App. A).
  eagle    — single decoder-layer draft with hidden-state recurrence
             (App. C / Li et al. 2024): node input = fuse(E(token), ĥ_parent),
             logits via the frozen base LM head. Simplified to prefix+self
             attention (no intra-tree ancestor attention) — see DESIGN.md §2.

Tree-node conventions (mirrored in rust/src/tree/):
  depth 1 = the "root" candidates sampled from the base model's own logits
  depth 1+i = candidates proposed by draft head i (i = 1..K)
"""

from typing import Dict, List

import jax
import jax.numpy as jnp

from .config import ModelConfig, HeadConfig, NUM_DRAFT_HEADS
from .kernels.ref import swiglu_ref, NEG_INF
from .model import rmsnorm, rope


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _dense(key, shape, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


def _decoder_layer_params(cfg: ModelConfig, key, prefix: str) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 7)
    return {
        prefix + "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        prefix + "wq": _dense(ks[0], (cfg.d_model, cfg.n_heads * cfg.head_dim)),
        prefix + "wk": _dense(ks[1], (cfg.d_model, cfg.kv_dim)),
        prefix + "wv": _dense(ks[2], (cfg.d_model, cfg.kv_dim)),
        prefix + "wo": _dense(ks[3], (cfg.n_heads * cfg.head_dim, cfg.d_model)),
        prefix + "ffn_norm": jnp.ones((cfg.d_model,), jnp.float32),
        prefix + "w1": _dense(ks[4], (cfg.d_model, cfg.d_ffn)),
        prefix + "w2": _dense(ks[5], (cfg.d_ffn, cfg.d_model)),
        prefix + "w3": _dense(ks[6], (cfg.d_model, cfg.d_ffn)),
    }


def init_head_params(cfg: ModelConfig, hc: HeadConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Flat name->array dict; sorted-name order is the AOT arg order."""
    params: Dict[str, jnp.ndarray] = {}
    keys = jax.random.split(key, 64)
    ki = iter(keys)
    d, v = cfg.d_model, cfg.vocab

    if hc.kind == "eagle":
        params["eg.fuse"] = _dense(next(ki), (2 * d, d))
        params.update(_decoder_layer_params(cfg, next(ki), "eg."))
        return params

    for i in range(1, NUM_DRAFT_HEADS + 1):
        pre = f"head{i}."
        d_in = d if hc.kind == "medusa" else d * (1 + i)
        params[pre + "win"] = _dense(next(ki), (d_in, d))
        for j in range(hc.mlp_layers - 1):
            params[pre + f"res{j}.w"] = _dense(next(ki), (d, d), scale=0.0)  # zero-init residual
        params[pre + "wout"] = _dense(next(ki), (d, v), 0.02)
    if hc.prefix_attn:
        params.update(_decoder_layer_params(cfg, next(ki), "prefix."))
    return params


def head_param_names(cfg: ModelConfig, hc: HeadConfig) -> List[str]:
    return sorted(init_head_params(cfg, hc, jax.random.PRNGKey(0)).keys())


# ---------------------------------------------------------------------------
# MLP head forward
# ---------------------------------------------------------------------------


def mlp_head_forward(hp: Dict[str, jnp.ndarray], hc: HeadConfig, i: int,
                     x_in: jnp.ndarray) -> jnp.ndarray:
    """Head i over pre-concatenated input x_in [..., d_in] -> logits [..., V]."""
    pre = f"head{i}."
    h = jax.nn.silu(x_in @ hp[pre + "win"])
    for j in range(hc.mlp_layers - 1):
        h = h + jax.nn.silu(h @ hp[pre + f"res{j}.w"])
    return h @ hp[pre + "wout"]


def medusa_draft(hp: Dict[str, jnp.ndarray], hc: HeadConfig, h: jnp.ndarray) -> jnp.ndarray:
    """h: [M, D] -> logits [M, K, V]. One call proposes for all K heads —
    sequential independence means no tree context is needed (the paper's
    Fig. 1 left)."""
    outs = [mlp_head_forward(hp, hc, i, h) for i in range(1, NUM_DRAFT_HEADS + 1)]
    return jnp.stack(outs, axis=1)


def hydra_draft(hp: Dict[str, jnp.ndarray], hc: HeadConfig, i: int,
                tok_emb: jnp.ndarray, h: jnp.ndarray, path_tokens: jnp.ndarray) -> jnp.ndarray:
    """Head i: h [M, D], path_tokens [M, i] (tree path from root, depths
    1..i) -> logits [M, V]. The embedding concat is the paper's Eq. (3)."""
    m = h.shape[0]
    embs = tok_emb[path_tokens].reshape(m, -1)   # [M, i*D]
    return mlp_head_forward(hp, hc, i, jnp.concatenate([h, embs], axis=-1))


# ---------------------------------------------------------------------------
# Incremental decoder layer (shared by prefix-attention and EAGLE)
# ---------------------------------------------------------------------------


def _layer_qkv(cfg: ModelConfig, lp, prefix, x):
    xn = rmsnorm(x, lp[prefix + "attn_norm"])
    return xn @ lp[prefix + "wq"], xn @ lp[prefix + "wk"], xn @ lp[prefix + "wv"]


def _layer_ffn(cfg: ModelConfig, lp, prefix, x):
    xn = rmsnorm(x, lp[prefix + "ffn_norm"])
    return swiglu_ref(xn, lp[prefix + "w1"], lp[prefix + "w2"], lp[prefix + "w3"])


def decoder_layer_full(cfg: ModelConfig, lp: Dict[str, jnp.ndarray], prefix: str,
                       x: jnp.ndarray, length: jnp.ndarray):
    """Causal decoder layer over a full sequence. x: [B, S, D], length: [B].
    Returns (out [B, S, D], lkv [B, 2, S, KVD]). Build-time training and
    the prefill entry both use this."""
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _layer_qkv(cfg, lp, prefix, x)
    q = rope(q.reshape(b, s, cfg.n_heads, cfg.head_dim), positions, cfg.rope_theta)
    k = rope(k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim), positions, cfg.rope_theta)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    lkv = jnp.stack([k.reshape(b, s, -1), v.reshape(b, s, -1)], axis=1)
    groups = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    valid = positions < length[:, None]
    causal = jnp.tril(jnp.ones((s, s), bool))[None] & valid[:, None, :]
    logits = jnp.einsum("bthd,bshd->bhts", q, kk) / (cfg.head_dim ** 0.5)
    logits = jnp.where(causal[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    attn = jnp.einsum("bhts,bshd->bthd", probs, vv).reshape(b, s, -1)
    out = x + attn @ lp[prefix + "wo"]
    out = out + _layer_ffn(cfg, lp, prefix, out)
    return out, lkv


def decoder_layer_step(cfg: ModelConfig, lp: Dict[str, jnp.ndarray], prefix: str,
                       x_new: jnp.ndarray, count: jnp.ndarray, cur_len: jnp.ndarray,
                       lkv: jnp.ndarray):
    """Append A new positions to a decoder layer's own KV cache and run them.

    x_new: [B, A, D] (rows >= count are padding); count/cur_len: [B];
    lkv: [B, 2, S, KVD]. New row j lands at absolute position cur_len + j.
    Returns (out [B, A, D], lkv', last [B, D] = out at row count-1).
    """
    b, a, d = x_new.shape
    s = lkv.shape[2]
    positions = cur_len[:, None] + jnp.arange(a)[None, :]            # [B, A]
    q, k, v = _layer_qkv(cfg, lp, prefix, x_new)
    q = rope(q.reshape(b, a, cfg.n_heads, cfg.head_dim), positions, cfg.rope_theta)
    k = rope(k.reshape(b, a, cfg.n_kv_heads, cfg.head_dim), positions, cfg.rope_theta)
    v = v.reshape(b, a, cfg.n_kv_heads, cfg.head_dim)

    # Scatter the new K/V rows at cur_len + j (j < count).
    new_rows = jnp.stack([k.reshape(b, a, -1), v.reshape(b, a, -1)], axis=1)  # [B,2,A,KVD]
    pos_grid = jnp.arange(s, dtype=jnp.int32)
    for j in range(a):
        dest = cur_len + j
        write = j < count
        sel = ((pos_grid[None] == dest[:, None]) & write[:, None])[:, None, :, None]
        lkv = jnp.where(sel, new_rows[:, :, j:j + 1], lkv)

    # Attend over the updated cache: query row j may see absolute pos <= cur_len+j.
    kk = lkv[:, 0].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    vv = lkv[:, 1].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    groups = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(kk, groups, axis=2)
    vv = jnp.repeat(vv, groups, axis=2)
    logits = jnp.einsum("bahd,bshd->bhas", q, kk) / (cfg.head_dim ** 0.5)
    allow = pos_grid[None, None] <= positions[:, :, None]            # [B, A, S]
    logits = jnp.where(allow[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    attn = jnp.einsum("bhas,bshd->bahd", probs, vv).reshape(b, a, -1)
    out = x_new + attn @ lp[prefix + "wo"]
    out = out + _layer_ffn(cfg, lp, prefix, out)
    idx = jnp.clip(count - 1, 0, a - 1)
    last = jnp.take_along_axis(out, idx[:, None, None], axis=1)[:, 0]
    return out, lkv, last


# ---------------------------------------------------------------------------
# Prefix-attention entry points (Hydra++)
# ---------------------------------------------------------------------------


def prefix_prefill(cfg: ModelConfig, hp, hidden_seq, length):
    """hidden_seq: [B, S, D] (base last-layer hiddens). Returns
    (enriched-last [B, D], lkv [B, 2, S, KVD])."""
    out, lkv = decoder_layer_full(cfg, hp, "prefix.", hidden_seq, length)
    b, s, d = hidden_seq.shape
    idx = jnp.clip(length - 1, 0, s - 1)
    last = jnp.take_along_axis(out, idx[:, None, None], axis=1)[:, 0]
    return last, lkv


def prefix_step(cfg: ModelConfig, hp, new_hidden, count, cur_len, lkv):
    """One serving step: feed the base hiddens of the newly committed tokens
    (queried ONCE per decoding step — paper §3.1(3)). Returns (enriched
    [B, D], lkv')."""
    _, lkv, last = decoder_layer_step(cfg, hp, "prefix.", new_hidden, count, cur_len, lkv)
    return last, lkv


# ---------------------------------------------------------------------------
# EAGLE entry points
# ---------------------------------------------------------------------------


def eagle_fuse(hp, tok_emb, tokens, hidden):
    """fuse(E(x_j), h_{j-1}): tokens [.., N], hidden [.., N, D] -> [.., N, D]."""
    e = tok_emb[tokens]
    return jnp.concatenate([e, hidden], axis=-1) @ hp["eg.fuse"]


def eagle_prefill(cfg: ModelConfig, hp, tok_emb, tokens, hidden_seq, length):
    """Build the draft layer's cache over the prompt. tokens: [B, S];
    hidden_seq: [B, S, D] base hiddens. Input at pos j fuses E(x_j) with
    h_{j-1} (h_{-1} = 0). Returns (f̂-last [B, D], ekv [B, 2, S, KVD])."""
    b, s, d = hidden_seq.shape
    h_prev = jnp.concatenate([jnp.zeros((b, 1, d), hidden_seq.dtype), hidden_seq[:, :-1]], axis=1)
    fused = eagle_fuse(hp, tok_emb, tokens, h_prev)
    out, ekv = decoder_layer_full(cfg, hp, "eg.", fused, length)
    idx = jnp.clip(length - 1, 0, s - 1)
    last = jnp.take_along_axis(out, idx[:, None, None], axis=1)[:, 0]
    return last, ekv


def eagle_step(cfg: ModelConfig, hp, tok_emb, lm_head, final_norm,
               tokens, h_parent, pos, cur_len, ekv):
    """Score N tree nodes at one depth. tokens: [B, N] (node tokens);
    h_parent: [B, N, D] (parent's estimated hidden); pos: [B, N] absolute
    positions; ekv: the committed draft cache. Nodes attend to the committed
    prefix and themselves (DESIGN.md §2 simplification). Returns
    (logits [B, N, V] for the node's child, ĥ_node [B, N, D])."""
    b, n = tokens.shape
    s = ekv.shape[2]
    fused = eagle_fuse(hp, tok_emb, tokens, h_parent)                 # [B, N, D]
    q, k, v = _layer_qkv(cfg, hp, "eg.", fused)
    q = rope(q.reshape(b, n, cfg.n_heads, cfg.head_dim), pos, cfg.rope_theta)
    k_self = rope(k.reshape(b, n, cfg.n_kv_heads, cfg.head_dim), pos, cfg.rope_theta)
    v_self = v.reshape(b, n, cfg.n_kv_heads, cfg.head_dim)

    kk = ekv[:, 0].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    vv = ekv[:, 1].reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    groups = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(kk, groups, axis=2)
    vv = jnp.repeat(vv, groups, axis=2)
    k_self_g = jnp.repeat(k_self, groups, axis=2)
    v_self_g = jnp.repeat(v_self, groups, axis=2)

    logits = jnp.einsum("bnhd,bshd->bhns", q, kk) / (cfg.head_dim ** 0.5)
    prefix_ok = jnp.arange(s)[None, None] < cur_len[:, None, None]    # [B, 1, S]
    logits = jnp.where(prefix_ok[:, None], logits, NEG_INF)
    self_logit = jnp.einsum("bnhd,bnhd->bhn", q, k_self_g)[..., None] / (cfg.head_dim ** 0.5)
    all_logits = jnp.concatenate([logits, self_logit], axis=-1)       # [B, H, N, S+1]
    probs = jax.nn.softmax(all_logits, axis=-1)
    attn = jnp.einsum("bhns,bshd->bnhd", probs[..., :s], vv)
    attn = attn + probs[..., s:].transpose(0, 2, 1, 3) * v_self_g
    attn = attn.reshape(b, n, -1)
    out = fused + attn @ hp["eg.wo"]
    out = out + _layer_ffn(cfg, hp, "eg.", out)
    head_logits = rmsnorm(out, final_norm) @ lm_head
    return head_logits, out


def eagle_extend(cfg: ModelConfig, hp, tok_emb, tokens, h_parent, count, cur_len, ekv):
    """Commit accepted tokens into the draft layer's cache (one cheap call
    per decoding step). tokens: [B, A]; h_parent: [B, A, D] = base hiddens
    of each token's predecessor. Returns (f̂-last [B, D], ekv')."""
    fused = eagle_fuse(hp, tok_emb, tokens, h_parent)
    _, ekv, last = decoder_layer_step(cfg, hp, "eg.", fused, count, cur_len, ekv)
    return last, ekv
