"""Shared model / serving configuration for the Hydra reproduction.

The three base-model sizes stand in for Vicuna 7B / 13B / 33B (see
DESIGN.md §2 — the paper's dynamics depend on the *relative* accuracy of
draft heads against a fixed base model, not on absolute scale). All shapes
here are baked into the AOT artifacts and mirrored by the Rust side via
artifacts/manifest.json.
"""

from dataclasses import dataclass, field
from typing import Dict, List

# ---------------------------------------------------------------------------
# Global serving shape constants (mirrored in rust/src/model/config.rs)
# ---------------------------------------------------------------------------

VOCAB_SIZE = 512          # 256 byte tokens + 256 BPE merges
SEQ_MAX = 384             # KV-cache slot length
NUM_DRAFT_HEADS = 4       # K in the paper; tree depth = K + 1 (root from base)
ACCEPT_MAX = NUM_DRAFT_HEADS + 1  # max committed tokens per decode step
BATCH_BUCKETS = [1, 2, 4, 8]
TREE_BUCKETS = [1, 4, 8, 16, 32, 64]   # packed tree-token buckets (T); 1 == AR decode
NODE_BUCKETS = [8, 16, 48]             # per-depth node buckets for seq.-dep. drafts
ROPE_THETA = 10000.0


@dataclass
class ModelConfig:
    """Base-transformer hyper-parameters (LLaMA-style)."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ffn: int
    vocab: int = VOCAB_SIZE
    seq_max: int = SEQ_MAX
    rope_theta: float = ROPE_THETA

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ffn, self.vocab
        per_layer = (
            d * d                              # wq
            + 2 * d * self.kv_dim              # wk, wv
            + d * d                            # wo
            + 3 * d * f                        # w1, w2, w3 (SwiGLU)
            + 2 * d                            # rmsnorm x2
        )
        return v * d + self.n_layers * per_layer + d + d * v  # emb + layers + final norm + lm head


# Paper-size mapping: 7B -> base-s, 13B -> base-m, 33B -> base-l.
SIZES: Dict[str, ModelConfig] = {
    "s": ModelConfig("s", d_model=96, n_layers=2, n_heads=4, n_kv_heads=2, d_ffn=256),
    "m": ModelConfig("m", d_model=128, n_layers=3, n_heads=4, n_kv_heads=2, d_ffn=352),
    "l": ModelConfig("l", d_model=192, n_layers=4, n_heads=6, n_kv_heads=2, d_ffn=512),
}


@dataclass
class HeadConfig:
    """Draft-model (head) configuration.

    kind:
      medusa   — sequentially-independent residual MLP (Cai et al. 2024)
      hydra    — sequentially-dependent MLP over [h ; E(path tokens)] (§3)
      eagle    — decoder-layer draft with hidden-state recurrence (App. C)
    mlp_layers — hidden-layer count of each head MLP (Hydra++ uses 4, §3.1)
    prefix_attn — extra decoder layer producing the draft input state (§3.1 / A.2)
    objective  — "ntp" (next-token) or "teacher" (self-distillation, §3.1 / A.1)
    noise_alpha — NEFT-style hidden-state noise strength (App. A.1); 0 = off
    """

    name: str
    kind: str = "hydra"
    mlp_layers: int = 1
    prefix_attn: bool = False
    objective: str = "ntp"
    noise_alpha: float = 0.0
    epochs_scale: float = 1.0   # Hydra++ trains 10x (paper §5)


# Every head variant trained by `make artifacts`.
# Core variants exist for all sizes; ablation variants only for base-s
# (the paper runs ablations on the 7B base).
CORE_HEAD_VARIANTS: List[HeadConfig] = [
    HeadConfig("medusa", kind="medusa", mlp_layers=1, objective="ntp"),
    HeadConfig("hydra", kind="hydra", mlp_layers=1, objective="ntp"),
    HeadConfig(
        "hydra_pp",
        kind="hydra",
        mlp_layers=4,
        prefix_attn=True,
        objective="teacher",
        epochs_scale=3.0,
    ),
]

ABLATION_HEAD_VARIANTS: List[HeadConfig] = [
    # Fig. 5: training-objective ablation on basic Hydra heads.
    HeadConfig("hydra_ntp_noise", kind="hydra", objective="ntp", noise_alpha=75.0),
    HeadConfig("hydra_teacher", kind="hydra", objective="teacher"),
    HeadConfig("hydra_teacher_noise", kind="hydra", objective="teacher", noise_alpha=75.0),
    # Fig. 6: architecture ablation — PrefixMLP vs plain MLP (teacher loss held fixed).
    HeadConfig("hydra_prefixmlp", kind="hydra", prefix_attn=True, objective="teacher"),
    # Fig. 10: EAGLE-style decoder-layer draft head.
    HeadConfig("eagle", kind="eagle", objective="teacher", epochs_scale=3.0),
]

ABLATION_SIZE = "s"


def head_variants_for_size(size: str) -> List[HeadConfig]:
    variants = list(CORE_HEAD_VARIANTS)
    if size == ABLATION_SIZE:
        variants += ABLATION_HEAD_VARIANTS
    return variants
