"""Training substrate: hand-rolled AdamW + cosine schedule + loss wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig, HeadConfig
from compile import model as M
from compile import train as T


def test_cosine_lr_shape():
    total = 100
    lrs = [float(T.cosine_lr(jnp.asarray(s, jnp.float32), total)) for s in range(total)]
    peak = max(lrs)
    assert abs(peak - 1e-3) < 1e-4
    # warmup rises
    assert lrs[0] < lrs[2] < lrs[4]
    # decays after peak
    assert lrs[-1] < lrs[total // 2] < peak
    assert lrs[-1] >= 1e-5 - 1e-9


def test_adamw_minimizes_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = T.adamw_init(params)

    def loss_fn(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(300):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = T.adamw_update(params, grads, opt, lr=0.05, wd=0.0)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_weight_decay_shrinks():
    params = {"x": jnp.asarray([1.0])}
    opt = T.adamw_init(params)
    zero_grads = {"x": jnp.asarray([0.0])}
    for _ in range(50):
        params, opt = T.adamw_update(params, zero_grads, opt, lr=0.1, wd=0.1)
    assert float(params["x"][0]) < 1.0


def test_batch_iter_windows():
    ids = np.arange(1000, dtype=np.int32)
    it = T.batch_iter(ids, batch=4, seq=16, seed=0)
    b1 = next(it)
    b2 = next(it)
    assert b1.shape == (4, 16)
    assert not np.array_equal(b1, b2)
    # windows are contiguous slices
    for row in b1:
        assert np.array_equal(row, np.arange(row[0], row[0] + 16))


CFG = ModelConfig("t", d_model=24, n_layers=1, n_heads=2, n_kv_heads=2,
                  d_ffn=32, seq_max=64)


def test_base_training_reduces_loss():
    rng = np.random.default_rng(0)
    # A highly learnable stream: repeating 16-token pattern.
    pattern = rng.integers(0, 64, 16)
    ids = np.tile(pattern, 400).astype(np.int32)
    params, log = T.train_base(CFG, ids, steps=80, batch=4, seq=32, log_every=40)
    assert log[-1]["loss"] < log[0]["loss"] * 0.95, log


def test_head_loss_decreases_for_each_objective():
    rng = np.random.default_rng(1)
    pattern = rng.integers(0, 64, 16)
    ids = np.tile(pattern, 300).astype(np.int32)
    base, _ = T.train_base(CFG, ids, steps=30, batch=4, seq=32, log_every=100)
    for hc in [
        HeadConfig("hydra", kind="hydra"),
        HeadConfig("hydra_teacher", kind="hydra", objective="teacher"),
        HeadConfig("medusa", kind="medusa"),
    ]:
        _, log = T.train_heads(CFG, hc, base, ids, steps=25, batch=4, seq=32,
                               log_every=100)
        assert log[-1]["loss"] < log[0]["loss"], (hc.name, log)
