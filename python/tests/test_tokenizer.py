"""Tokenizer: BPE-lite training, inference/bulk-encode equivalence, roundtrip."""

import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data
from compile.tokenizer import Tokenizer, train_merges, N_BYTE_TOKENS


@pytest.fixture(scope="module")
def tok():
    corpus = data.gen_corpus(n_examples=150)
    return Tokenizer(train_merges(corpus[:20000], 64)), corpus


def test_train_learns_merges(tok):
    t, _ = tok
    assert len(t.merges) == 64
    assert t.vocab_size == N_BYTE_TOKENS + 64


def test_roundtrip(tok):
    t, corpus = tok
    for a in range(0, 5000, 517):
        s = corpus[a:a + 73]
        assert t.decode(t.encode(s)) == s


@settings(max_examples=30, deadline=None)
@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126), max_size=60))
def test_roundtrip_arbitrary_ascii(s):
    corpus = data.gen_corpus(n_examples=50)
    t = Tokenizer(train_merges(corpus[:5000], 32))
    assert t.decode(t.encode(s)) == s


def test_encode_corpus_matches_encode(tok):
    """Bulk (rank-order) encoding must equal inference (lowest-rank-first)."""
    t, corpus = tok
    for a in range(0, 3000, 301):
        s = corpus[a:a + 120]
        assert list(t.encode_corpus(s)) == t.encode(s), s


def test_encode_ids_in_range(tok):
    t, corpus = tok
    ids = t.encode(corpus[:500])
    assert all(0 <= i < t.vocab_size for i in ids)
    assert len(ids) < 500  # merges must compress


def test_save_load_roundtrip(tok, tmp_path):
    t, corpus = tok
    p = tmp_path / "tok.json"
    t.save(str(p))
    t2 = Tokenizer.load(str(p))
    s = corpus[100:220]
    assert t.encode(s) == t2.encode(s)


def test_determinism():
    c1 = data.gen_corpus(seed=5, n_examples=40)
    c2 = data.gen_corpus(seed=5, n_examples=40)
    assert c1 == c2
    assert train_merges(c1[:4000], 16) == train_merges(c2[:4000], 16)


def test_overlapping_pair_greedy_left():
    """'aaaa' with merge (a,a) -> two merged tokens, greedy left-to-right."""
    t = Tokenizer([(97, 97)])
    assert t.encode("aaaa") == [256, 256]
    assert t.encode("aaa") == [256, 97]
    assert list(t.encode_corpus("aaaa")) == [256, 256]
    assert list(t.encode_corpus("aaa")) == [256, 97]
