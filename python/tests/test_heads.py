"""Draft-head architecture invariants — most importantly the paper's core
distinction: Medusa heads are sequentially INDEPENDENT (changing candidate
path tokens cannot change their output) while Hydra heads are sequentially
DEPENDENT (it must)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig, HeadConfig, NUM_DRAFT_HEADS
from compile import heads as H
from compile import model as M

CFG = ModelConfig("t", d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                  d_ffn=64, seq_max=64)


@pytest.fixture(scope="module")
def base():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_medusa_head_shapes(base):
    hc = HeadConfig("medusa", kind="medusa")
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(1))
    h = jnp.ones((8, CFG.d_model))
    out = H.medusa_draft(hp, hc, h)
    assert out.shape == (8, NUM_DRAFT_HEADS, CFG.vocab)


def test_hydra_head_is_sequentially_dependent(base):
    hc = HeadConfig("hydra", kind="hydra")
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((4, CFG.d_model)), jnp.float32)
    path1 = jnp.asarray([[3, 7], [1, 2], [9, 9], [0, 5]], jnp.int32)
    path2 = path1.at[:, 1].set(jnp.asarray([8, 3, 1, 6]))
    l1 = H.hydra_draft(hp, hc, 2, base["tok_emb"], h, path1)
    l2 = H.hydra_draft(hp, hc, 2, base["tok_emb"], h, path2)
    assert l1.shape == (4, CFG.vocab)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4, \
        "hydra head must depend on the candidate path"


def test_hydra_head_input_width_grows():
    hc = HeadConfig("hydra", kind="hydra")
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(3))
    for i in range(1, NUM_DRAFT_HEADS + 1):
        assert hp[f"head{i}.win"].shape == (CFG.d_model * (1 + i), CFG.d_model)


def test_mlp_layers_add_residual_blocks():
    hc = HeadConfig("hydra_pp", kind="hydra", mlp_layers=4)
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(4))
    for i in range(1, NUM_DRAFT_HEADS + 1):
        for j in range(3):
            assert f"head{i}.res{j}.w" in hp
    # Zero-init residuals: 4-layer head == 1-layer head at init.
    hc1 = HeadConfig("hydra", kind="hydra", mlp_layers=1)
    x = jnp.ones((2, CFG.d_model * 2))
    out4 = H.mlp_head_forward(hp, hc, 1, x)
    hp1 = {k: v for k, v in hp.items() if "res" not in k}
    out1 = H.mlp_head_forward(hp1, hc1, 1, x)
    np.testing.assert_allclose(np.asarray(out4), np.asarray(out1), atol=1e-6)


def test_prefix_step_matches_full(base):
    """Incremental prefix-attention (serving path) must equal the full
    causal layer (training path) on the same inputs."""
    hc = HeadConfig("hydra_pp", kind="hydra", mlp_layers=1, prefix_attn=True)
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(5))
    rng = np.random.default_rng(1)
    b, s, d = 2, CFG.seq_max, CFG.d_model
    n0, n_new = 10, 3
    hseq = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)

    # Full pass over n0 + n_new positions.
    full_out, _ = H.decoder_layer_full(
        CFG, hp, "prefix.", hseq, jnp.asarray([n0 + n_new] * b, jnp.int32))

    # Incremental: prefill n0, then step the next n_new.
    _, lkv = H.prefix_prefill(CFG, hp, hseq, jnp.asarray([n0] * b, jnp.int32))
    new_h = hseq[:, n0:n0 + 5, :]  # A = 5 rows, only first n_new valid
    last, _ = H.prefix_step(CFG, hp, new_h, jnp.asarray([n_new] * b, jnp.int32),
                            jnp.asarray([n0] * b, jnp.int32), lkv)
    want = full_out[:, n0 + n_new - 1]
    np.testing.assert_allclose(np.asarray(last), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_eagle_prefill_and_extend_consistent(base):
    """EAGLE's incremental cache extension must reproduce the prefill path:
    prefill(n0+k tokens) == prefill(n0) + extend(k tokens)."""
    hc = HeadConfig("eagle", kind="eagle")
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(6))
    rng = np.random.default_rng(2)
    b, s, d = 1, CFG.seq_max, CFG.d_model
    n0, k = 12, 3
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (b, s)), jnp.int32)
    hseq = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)

    last_full, _ = H.eagle_prefill(CFG, hp, base["tok_emb"], tokens, hseq,
                                   jnp.asarray([n0 + k], jnp.int32))

    _, ekv = H.eagle_prefill(CFG, hp, base["tok_emb"], tokens, hseq,
                             jnp.asarray([n0], jnp.int32))
    # extend with tokens n0..n0+k-1; parent hidden = hseq[n0-1 .. n0+k-2]
    etoks = tokens[:, n0:n0 + 5]
    hpar = hseq[:, n0 - 1:n0 + 4, :]
    last_inc, _ = H.eagle_extend(CFG, hp, base["tok_emb"], etoks, hpar,
                                 jnp.asarray([k], jnp.int32),
                                 jnp.asarray([n0], jnp.int32), ekv)
    np.testing.assert_allclose(np.asarray(last_inc), np.asarray(last_full),
                               rtol=2e-4, atol=2e-4)


def test_eagle_step_shapes(base):
    hc = HeadConfig("eagle", kind="eagle")
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(7))
    rng = np.random.default_rng(3)
    n = 8
    ekv = jnp.zeros((1, 2, CFG.seq_max, CFG.kv_dim))
    logits, h_out = H.eagle_step(
        CFG, hp, base["tok_emb"], base["lm_head"], base["final_norm"],
        jnp.asarray(rng.integers(0, CFG.vocab, (1, n)), jnp.int32),
        jnp.asarray(rng.standard_normal((1, n, CFG.d_model)), jnp.float32),
        jnp.asarray([[5] * n], jnp.int32),
        jnp.asarray([5], jnp.int32), ekv)
    assert logits.shape == (1, n, CFG.vocab)
    assert h_out.shape == (1, n, CFG.d_model)
    assert np.isfinite(np.asarray(logits)).all()


def test_decoder_layer_step_writes_cache(base):
    hc = HeadConfig("hydra_pp", kind="hydra", prefix_attn=True)
    hp = H.init_head_params(CFG, hc, jax.random.PRNGKey(8))
    b, s = 1, CFG.seq_max
    lkv = jnp.zeros((b, 2, s, CFG.kv_dim))
    x = jnp.ones((b, 5, CFG.d_model))
    _, lkv2, _ = H.decoder_layer_step(
        CFG, hp, "prefix.", x, jnp.asarray([2], jnp.int32),
        jnp.asarray([7], jnp.int32), lkv)
    lkv2 = np.asarray(lkv2)
    # Rows 7, 8 written; row 9 (beyond count) untouched (zero).
    assert np.abs(lkv2[0, :, 7]).max() > 0
    assert np.abs(lkv2[0, :, 8]).max() > 0
    assert np.abs(lkv2[0, :, 9]).max() == 0
    assert np.abs(lkv2[0, :, 6]).max() == 0
