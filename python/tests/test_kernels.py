"""L1 correctness: Pallas tree-attention vs the pure-jnp oracle.

Hypothesis sweeps shapes, masks, prefix lengths and dtypes — the CORE
correctness signal for the kernel that sits inside every verify artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref, swiglu_ref
from compile.kernels.tree_attention import (
    tree_attention, tree_attention_batched_ref_layout)


def random_tree_mask(rng, t):
    """Random parent pointers -> ancestor-or-self mask (valid tree shape)."""
    parent = [-1] + [int(rng.integers(0, i)) for i in range(1, t)]
    m = np.zeros((t, t), np.int32)
    for i in range(t):
        j = i
        while j != -1:
            m[i, j] = 1
            j = parent[j]
    return m


def make_inputs(rng, t, h, kvh, hd, s, cur_len):
    q = rng.standard_normal((t, h, hd)).astype(np.float32)
    ck = rng.standard_normal((s, kvh, hd)).astype(np.float32)
    cv = rng.standard_normal((s, kvh, hd)).astype(np.float32)
    tk = rng.standard_normal((t, kvh, hd)).astype(np.float32)
    tv = rng.standard_normal((t, kvh, hd)).astype(np.float32)
    am = random_tree_mask(rng, t)
    return (jnp.asarray(q), jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(tk),
            jnp.asarray(tv), jnp.asarray(cur_len, jnp.int32), jnp.asarray(am))


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([1, 4, 8, 16, 32, 64]),
    h=st.sampled_from([2, 4, 6]),
    hd=st.sampled_from([8, 16, 24, 32]),
    s_blocks=st.integers(1, 3),
    cur_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_tree_attention_matches_ref(t, h, hd, s_blocks, cur_frac, seed):
    kvh = h if h == 2 else h // 2
    s = 128 * s_blocks
    cur_len = int(cur_frac * s)
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, t, h, kvh, hd, s, cur_len)
    ref = tree_attention_ref(*args)
    out = tree_attention_batched_ref_layout(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_tree_attention_zero_prefix():
    """cur_len = 0: attention over the tree only (first decode after empty cache)."""
    rng = np.random.default_rng(0)
    args = make_inputs(rng, 8, 4, 2, 16, 128, 0)
    ref = tree_attention_ref(*args)
    out = tree_attention_batched_ref_layout(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
    assert np.isfinite(np.asarray(out)).all()


def test_tree_attention_full_prefix():
    rng = np.random.default_rng(1)
    args = make_inputs(rng, 16, 4, 2, 24, 384, 384)
    ref = tree_attention_ref(*args)
    out = tree_attention_batched_ref_layout(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_tree_attention_chain_mask_equals_causal():
    """A path tree (each node's parent is the previous node) must equal
    ordinary causal attention over prefix+chain."""
    rng = np.random.default_rng(2)
    t, h, kvh, hd, s, cur = 8, 4, 2, 16, 128, 40
    q = rng.standard_normal((t, h, hd)).astype(np.float32)
    ck = rng.standard_normal((s, kvh, hd)).astype(np.float32)
    cv = rng.standard_normal((s, kvh, hd)).astype(np.float32)
    tk = rng.standard_normal((t, kvh, hd)).astype(np.float32)
    tv = rng.standard_normal((t, kvh, hd)).astype(np.float32)
    chain = np.tril(np.ones((t, t), np.int32))
    out = tree_attention_batched_ref_layout(
        jnp.asarray(q), jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(tk),
        jnp.asarray(tv), jnp.asarray(cur, jnp.int32), jnp.asarray(chain))
    ref = tree_attention_ref(
        jnp.asarray(q), jnp.asarray(ck), jnp.asarray(cv), jnp.asarray(tk),
        jnp.asarray(tv), jnp.asarray(cur, jnp.int32), jnp.asarray(chain))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_tree_attention_batched_layout():
    """Direct batched entry ([B,H,T,hd] layouts) agrees with per-sequence calls."""
    rng = np.random.default_rng(3)
    b, t, h, kvh, hd, s = 4, 16, 4, 2, 16, 256
    q = rng.standard_normal((b, h, t, hd)).astype(np.float32)
    ck = rng.standard_normal((b, kvh, s, hd)).astype(np.float32)
    cv = rng.standard_normal((b, kvh, s, hd)).astype(np.float32)
    tk = rng.standard_normal((b, kvh, t, hd)).astype(np.float32)
    tv = rng.standard_normal((b, kvh, t, hd)).astype(np.float32)
    lens = np.array([[0], [10], [128], [256]], np.int32)
    masks = np.stack([random_tree_mask(rng, t) for _ in range(b)])
    out = tree_attention(jnp.asarray(q), jnp.asarray(ck), jnp.asarray(cv),
                         jnp.asarray(tk), jnp.asarray(tv), jnp.asarray(lens),
                         jnp.asarray(masks))
    for i in range(b):
        ref = tree_attention_ref(
            jnp.asarray(q[i].transpose(1, 0, 2)),
            jnp.asarray(ck[i].transpose(1, 0, 2)),
            jnp.asarray(cv[i].transpose(1, 0, 2)),
            jnp.asarray(tk[i].transpose(1, 0, 2)),
            jnp.asarray(tv[i].transpose(1, 0, 2)),
            jnp.asarray(lens[i, 0], jnp.int32), jnp.asarray(masks[i]))
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.transpose(1, 0, 2)),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([1, 7, 16]), d=st.sampled_from([8, 32]),
       f=st.sampled_from([16, 48]), seed=st.integers(0, 2**31 - 1))
def test_swiglu_ref_matches_manual(n, d, f, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    w1 = rng.standard_normal((d, f)).astype(np.float32)
    w2 = rng.standard_normal((f, d)).astype(np.float32)
    w3 = rng.standard_normal((d, f)).astype(np.float32)
    got = np.asarray(swiglu_ref(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w2), jnp.asarray(w3)))
    a = x @ w1
    ref = ((a / (1 + np.exp(-a))) * (x @ w3)) @ w2
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_tree_attention_ignores_stale_cache_rows():
    """Rows of the cache beyond cur_len must not affect the output —
    the invariant that makes slot reuse in the Rust cache manager safe."""
    rng = np.random.default_rng(4)
    t, h, kvh, hd, s, cur = 4, 4, 2, 16, 128, 30
    args = list(make_inputs(rng, t, h, kvh, hd, s, cur))
    out1 = tree_attention_batched_ref_layout(*args)
    ck = np.asarray(args[1]).copy()
    cv = np.asarray(args[2]).copy()
    ck[cur:] = 1e6   # poison stale rows
    cv[cur:] = -1e6
    args[1], args[2] = jnp.asarray(ck), jnp.asarray(cv)
    out2 = tree_attention_batched_ref_layout(*args)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)
