"""Synthetic corpus generator: determinism, category coverage, wire format."""

import random

from compile import data


def test_corpus_deterministic():
    assert data.gen_corpus(seed=3, n_examples=50) == data.gen_corpus(seed=3, n_examples=50)
    assert data.gen_corpus(seed=3, n_examples=50) != data.gen_corpus(seed=4, n_examples=50)


def test_corpus_wire_format():
    c = data.gen_corpus(n_examples=30)
    assert "<user>" in c and "<bot>" in c and "<end>" in c
    # Every turn closes.
    assert c.count("<user>") == c.count("<end>")


def test_eval_prompts_cover_categories():
    prompts = data.gen_eval_prompts(per_category=5)
    cats = {p["category"] for p in prompts}
    assert cats == set(data.CATEGORIES)
    ids = [p["id"] for p in prompts]
    assert len(ids) == len(set(ids)) == 5 * len(data.CATEGORIES)


def test_eval_prompts_disjoint_from_training():
    """Eval uses a different seed stream; prompt texts shouldn't all appear
    verbatim in the training corpus."""
    corpus = data.gen_corpus(n_examples=500)
    prompts = data.gen_eval_prompts(per_category=10)
    missing = sum(1 for p in prompts if p["prompt"] not in corpus)
    assert missing > 0


def test_all_generators_produce_nonempty():
    rng = random.Random(0)
    for cat in data.CATEGORIES:
        for _ in range(20):
            ex = data.gen_example(rng, cat)
            assert ex["prompt"].strip() and ex["answer"].strip()
            assert ex["category"] == cat


def test_math_answers_correct():
    rng = random.Random(1)
    for _ in range(50):
        ex = data.gen_example(rng, "math")
        if "+" in ex["prompt"] and "=" in ex["answer"]:
            lhs, rhs = ex["answer"].rstrip(".").split("=")
            a, b = lhs.split("+")
            assert int(a) + int(b) == int(rhs)


def test_translation_is_deterministic_mapping():
    rng1, rng2 = random.Random(7), random.Random(7)
    e1 = data.gen_example(rng1, "translation")
    e2 = data.gen_example(rng2, "translation")
    assert e1 == e2
    # same word -> same cipher token across examples
    assert data._cipher_word("alice") == data._cipher_word("alice")
    assert data._cipher_word("alice") != data._cipher_word("bob")
