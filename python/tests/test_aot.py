"""AOT plumbing: HTB1 tensor binary roundtrip and HLO-text lowering."""

import json
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot


def test_write_tensors_roundtrip(tmp_path):
    tensors = {
        "w.a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "w.b": np.asarray([-1, 2, -3], dtype=np.int32),
    }
    path = tmp_path / "t.bin"
    aot.write_tensors(str(path), tensors)
    raw = path.read_bytes()
    assert raw[:4] == b"HTB1"
    hlen = struct.unpack("<I", raw[4:8])[0]
    header = json.loads(raw[8:8 + hlen])
    payload = raw[8 + hlen:]
    names = [e["name"] for e in header["tensors"]]
    assert names == sorted(names)
    for e in header["tensors"]:
        arr = tensors[e["name"]]
        dtype = np.float32 if e["dtype"] == "f32" else np.int32
        got = np.frombuffer(
            payload[e["offset"]:e["offset"] + e["nbytes"]], dtype=dtype
        ).reshape(e["shape"])
        np.testing.assert_array_equal(got, arr)


def test_write_tensors_rejects_f64(tmp_path):
    with pytest.raises(AssertionError):
        aot.write_tensors(str(tmp_path / "bad.bin"), {"x": np.zeros(3)})  # f64


def test_to_hlo_text_lowers_simple_fn():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_to_hlo_text_lowers_pallas_kernel():
    """The verify artifacts embed the Pallas tree-attention kernel; its
    interpret-mode lowering must produce plain HLO text."""
    from compile.kernels.tree_attention import tree_attention

    def fn(q, ck, cv, tk, tv, ln, am):
        return (tree_attention(q, ck, cv, tk, tv, ln, am),)

    b, h, kvh, t, hd, s = 1, 2, 2, 4, 8, 128
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((b, h, t, hd), f32),
        jax.ShapeDtypeStruct((b, kvh, s, hd), f32),
        jax.ShapeDtypeStruct((b, kvh, s, hd), f32),
        jax.ShapeDtypeStruct((b, kvh, t, hd), f32),
        jax.ShapeDtypeStruct((b, kvh, t, hd), f32),
        jax.ShapeDtypeStruct((b, 1), jnp.int32),
        jax.ShapeDtypeStruct((b, t, t), jnp.int32),
    ]
    lowered = jax.jit(fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret mode must not leave an unexecutable custom-call
    assert "tpu_custom_call" not in text
