"""L2 base-model invariants: decode parity, tree verification correctness,
prefill masking, commit semantics. These pin the exact contracts the Rust
engine relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import SIZES, ModelConfig, ACCEPT_MAX
from compile import model as M

CFG = ModelConfig("t", d_model=32, n_layers=2, n_heads=4, n_kv_heads=2,
                  d_ffn=64, seq_max=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(11))


def _prefill(params, toks, lens):
    return M.prefill(CFG, params, jnp.asarray(toks), jnp.asarray(lens, jnp.int32))


def test_param_shapes(params):
    assert params["tok_emb"].shape == (CFG.vocab, 32)
    assert params["layer00.wk"].shape == (32, CFG.kv_dim)
    assert CFG.kv_dim == 16


def test_prefill_padding_invariance(params):
    """Tokens beyond `length` must not affect outputs."""
    rng = np.random.default_rng(0)
    toks = np.zeros((1, CFG.seq_max), np.int32)
    toks[0, :20] = rng.integers(0, CFG.vocab, 20)
    h1, l1, kv1, hs1 = _prefill(params, toks, [20])
    toks2 = toks.copy()
    toks2[0, 20:] = rng.integers(0, CFG.vocab, CFG.seq_max - 20)
    h2, l2, kv2, hs2 = _prefill(params, toks2, [20])
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(kv1)[:, :, :, :20], np.asarray(kv2)[:, :, :, :20], atol=1e-5)
    np.testing.assert_allclose(np.asarray(hs1)[:, :20], np.asarray(hs2)[:, :20], atol=1e-5)


def test_prefill_matches_train_forward(params):
    rng = np.random.default_rng(1)
    n = 25
    toks = np.zeros((1, CFG.seq_max), np.int32)
    toks[0, :n] = rng.integers(0, CFG.vocab, n)
    _, last_logits, _, _ = _prefill(params, toks, [n])
    full = M.train_forward(CFG, params, jnp.asarray(toks[:, :n]))
    np.testing.assert_allclose(np.asarray(last_logits)[0], np.asarray(full)[0, -1],
                               rtol=1e-4, atol=1e-4)


def test_ar_decode_parity(params):
    """prefill + verify(T=1) + commit == argmax decode with full forward."""
    rng = np.random.default_rng(2)
    lens = [10, 17]
    B = 2
    toks = np.zeros((B, CFG.seq_max), np.int32)
    for b, L in enumerate(lens):
        toks[b, :L] = rng.integers(0, CFG.vocab, L)
    _, last_logits, kv, _ = _prefill(params, toks, lens)
    cur = np.array(lens, np.int32)
    seqs = [list(toks[b, :lens[b]]) for b in range(B)]
    root = np.argmax(np.asarray(last_logits), -1)
    for _ in range(6):
        logits, hidden, tree_kv = M.verify(
            CFG, params, jnp.asarray(root.reshape(B, 1).astype(np.int32)),
            jnp.asarray(cur.reshape(B, 1).astype(np.int32)),
            jnp.asarray(cur), jnp.ones((B, 1, 1), jnp.int32), kv)
        kv, _ = M.commit(kv, tree_kv, hidden,
                         jnp.zeros((B, ACCEPT_MAX), jnp.int32),
                         jnp.ones((B,), jnp.int32), jnp.asarray(cur))
        for b in range(B):
            seqs[b].append(int(root[b]))
        cur = cur + 1
        root = np.argmax(np.asarray(logits)[:, 0], -1)
    for b in range(B):
        ref = list(toks[b, :lens[b]])
        for _ in range(6):
            lg = M.train_forward(CFG, params, jnp.asarray([ref], jnp.int32))
            ref.append(int(np.argmax(np.asarray(lg)[0, -1])))
        assert ref == seqs[b]


def test_verify_tree_equals_sequential(params):
    """Every root-to-node path in a verified tree must produce the same
    logits as running that path sequentially — the correctness property
    that makes tree speculation sound (paper §2)."""
    rng = np.random.default_rng(3)
    n = 12
    toks = np.zeros((1, CFG.seq_max), np.int32)
    toks[0, :n] = rng.integers(0, CFG.vocab, n)
    _, _, kv, _ = _prefill(params, toks, [n])

    parent = [-1, 0, 0, 1, 1, 2, 3]
    tree_tok = np.array([[3, 7, 11, 2, 9, 4, 6]], np.int32)
    t = len(parent)
    anc = np.zeros((1, t, t), np.int32)
    depth = np.zeros(t, np.int32)
    for i in range(t):
        j = i
        while j != -1:
            anc[0, i, j] = 1
            j = parent[j]
        depth[i] = anc[0, i].sum() - 1
    pos = (n + depth)[None].astype(np.int32)
    logits, _, _ = M.verify(CFG, params, jnp.asarray(tree_tok), jnp.asarray(pos),
                            jnp.asarray([n], jnp.int32), jnp.asarray(anc), kv)
    logits = np.asarray(logits)[0]
    for node in range(t):
        path, j = [], node
        while j != -1:
            path.append(j)
            j = parent[j]
        path.reverse()
        seq = list(toks[0, :n]) + [int(tree_tok[0, k]) for k in path]
        full = M.train_forward(CFG, params, jnp.asarray([seq], jnp.int32))
        np.testing.assert_allclose(logits[node], np.asarray(full)[0, -1],
                                   rtol=2e-4, atol=2e-4)


def test_commit_scatter_semantics(params):
    """commit writes accepted rows at cur_len+j and leaves the rest alone."""
    B, L2, S, KVD, T, D = 2, CFG.n_layers, CFG.seq_max, CFG.kv_dim, 8, CFG.d_model
    rng = np.random.default_rng(4)
    kv = rng.standard_normal((B, L2, 2, S, KVD)).astype(np.float32)
    tree_kv = rng.standard_normal((B, L2, 2, T, KVD)).astype(np.float32)
    hidden = rng.standard_normal((B, T, D)).astype(np.float32)
    accept_idx = np.array([[0, 3, 5, 0, 0], [2, 0, 0, 0, 0]], np.int32)
    accept_len = np.array([3, 1], np.int32)
    cur_len = np.array([10, 40], np.int32)
    kv2, gath = M.commit(jnp.asarray(kv), jnp.asarray(tree_kv), jnp.asarray(hidden),
                         jnp.asarray(accept_idx), jnp.asarray(accept_len),
                         jnp.asarray(cur_len))
    kv2 = np.asarray(kv2)
    for b in range(B):
        for j in range(5):
            if j < accept_len[b]:
                np.testing.assert_allclose(
                    kv2[b, :, :, cur_len[b] + j], tree_kv[b, :, :, accept_idx[b, j]])
            else:
                np.testing.assert_allclose(
                    kv2[b, :, :, cur_len[b] + j], kv[b, :, :, cur_len[b] + j])
        np.testing.assert_allclose(kv2[b, :, :, :cur_len[b]], kv[b, :, :, :cur_len[b]])
    gath = np.asarray(gath)
    np.testing.assert_allclose(gath[0, 1], hidden[0, 3])
    np.testing.assert_allclose(gath[1, 0], hidden[1, 2])


def test_verify_batch_independence(params):
    """Each batch row's verify output depends only on that row."""
    rng = np.random.default_rng(5)
    lens = [8, 30]
    toks = np.zeros((2, CFG.seq_max), np.int32)
    for b, L in enumerate(lens):
        toks[b, :L] = rng.integers(0, CFG.vocab, L)
    _, _, kv, _ = _prefill(params, toks, lens)
    T = 4
    tree_tok = rng.integers(0, CFG.vocab, (2, T)).astype(np.int32)
    anc = np.tril(np.ones((T, T), np.int32))[None].repeat(2, 0)
    pos = np.stack([lens[0] + np.arange(T), lens[1] + np.arange(T)]).astype(np.int32)
    lg2, _, _ = M.verify(CFG, params, jnp.asarray(tree_tok), jnp.asarray(pos),
                         jnp.asarray(lens, jnp.int32), jnp.asarray(anc), kv)
    # single-row run of row 0
    _, _, kv0, _ = _prefill(params, toks[:1], lens[:1])
    lg1, _, _ = M.verify(CFG, params, jnp.asarray(tree_tok[:1]), jnp.asarray(pos[:1]),
                         jnp.asarray(lens[:1], jnp.int32), jnp.asarray(anc[:1]), kv0)
    np.testing.assert_allclose(np.asarray(lg2)[0], np.asarray(lg1)[0], rtol=1e-4, atol=1e-4)


def test_rope_position_shift():
    """RoPE is relative: equal queries/keys at shifted positions give the
    same attention pattern (sanity for tree position handling)."""
    x = jnp.ones((1, 4, 2, 16))
    p1 = jnp.array([[0, 1, 2, 3]])
    p2 = jnp.array([[10, 11, 12, 13]])
    r1 = M.rope(x, p1, 10000.0)
    r2 = M.rope(x, p2, 10000.0)
    dots1 = np.einsum("bthd,bshd->bts", np.asarray(r1), np.asarray(r1))
    dots2 = np.einsum("bthd,bshd->bts", np.asarray(r2), np.asarray(r2))
    np.testing.assert_allclose(dots1, dots2, rtol=1e-4, atol=1e-4)
