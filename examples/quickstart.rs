//! Quickstart: load the AOT artifacts, spin up a Hydra++ engine, and
//! generate a completion with speculative tree decoding.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --size s|m|l  --variant ar|medusa|hydra|hydra_pp|eagle
//!        --prompt "..."  --max-new 64
//!
//! Next steps: `serve_and_query` for the TCP front-end (streaming +
//! per-request params), `shared_prefix_serving` for the prefix-reuse KV
//! cache (shared-prompt admissions restored by copy instead of prefill),
//! `batched_throughput` for continuous batching under load.

use hydra_serve::draft;
use hydra_serve::engine::{Engine, EngineConfig, Request, SamplingParams};
use hydra_serve::runtime::Runtime;
use hydra_serve::tokenizer::{format_prompt, Tokenizer, STOP_TEXT};
use hydra_serve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let variant = args.str_or("variant", "hydra_pp");
    let prompt = args.str_or("prompt", "tell me about alice.");
    let max_new = args.usize_or("max-new", 64);

    // 1. Open the artifacts (manifest + HLO programs + weights).
    let rt = Runtime::new(hydra_serve::artifacts_dir())?;
    let tok = Tokenizer::load(&rt.manifest.dir.join("tokenizer.json"))?;
    println!(
        "loaded artifacts: {} executables, base-{size} = {:.2}M params",
        rt.manifest.executables.len(),
        rt.manifest.dims(&size)?.params as f64 / 1e6
    );

    // 2. Build the engine with the tuned (or default) decoding tree.
    let tree = draft::tuned_tree(&rt.manifest, &size, &variant, 1)?;
    println!("decoding tree: {} nodes, depth {}", tree.len(), tree.max_depth());
    let mut engine = Engine::new(
        &rt,
        EngineConfig {
            size,
            variant: variant.clone(),
            tree,
            batch: 1,
            seed: 42,
        },
    )?;

    // 3. Admit a request and decode. Generation knobs (acceptance mode,
    //    budget, stop marker) ride on the request's SamplingParams.
    let params = SamplingParams {
        max_new,
        stop_ids: tok.encode(STOP_TEXT),
        ..SamplingParams::default()
    };
    engine.admit(vec![Request::new(0, tok.encode(&format_prompt(&prompt)), params)])?;
    let t0 = std::time::Instant::now();
    engine.run_to_completion()?;
    let dt = t0.elapsed().as_secs_f64();

    let out = engine.take_outputs().pop().unwrap();
    let mut text = tok.decode(&out.generated);
    if let Some(p) = text.find(STOP_TEXT) {
        text.truncate(p);
    }
    println!("\nprompt : {prompt}");
    println!("output : {}", text.trim());
    println!(
        "\n{} tokens in {dt:.2}s = {:.1} tok/s | {} steps | mean acceptance {:.2} ({})",
        out.generated.len(),
        out.generated.len() as f64 / dt,
        out.steps,
        out.mean_accept_len,
        draft::label(&variant),
    );
    Ok(())
}
