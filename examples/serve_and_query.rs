//! Serving front-end demo: spawn the TCP JSON-lines server in-process,
//! connect several clients concurrently, and print the exchanges — the
//! request path is pure Rust + PJRT (Python was only used at build time).
//!
//!     cargo run --release --example serve_and_query

use std::sync::atomic::Ordering;

use hydra_serve::server::{spawn_local, Client};
use hydra_serve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let variant = args.str_or("variant", "hydra_pp");
    let batch = args.usize_or("batch", 4);

    let (port, shutdown, handle) =
        spawn_local(hydra_serve::artifacts_dir(), size, variant, batch)?;
    println!("server starting on 127.0.0.1:{port} (compiling executables)…");

    let prompts = [
        "tell me about alice.",
        "compute 17 + 25.",
        "who is frank?",
        "describe a day for judy in tokyo.",
    ];
    let addr = format!("127.0.0.1:{port}");

    // Query concurrently from separate client threads; the server batches
    // them into one engine (continuous batching).
    let mut joins = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let addr = addr.clone();
        let p = p.to_string();
        joins.push(std::thread::spawn(move || -> anyhow::Result<(usize, String)> {
            let mut c = Client::connect(&addr)?;
            let resp = c.generate(&p, 48)?;
            Ok((i, resp.to_string()))
        }));
    }
    for j in joins {
        let (i, resp) = j.join().expect("client thread")?;
        println!("\nclient {i} <- {resp}");
    }

    shutdown.store(true, Ordering::Relaxed);
    let _ = handle.join();
    println!("\nserver stopped.");
    Ok(())
}
