//! Serving front-end demo: spawn the TCP JSON-lines server in-process and
//! exercise the per-request generation API — concurrent clients with
//! different acceptance modes batched into one engine, plus a streaming
//! session that prints delta frames as tokens commit. The request path is
//! pure Rust + PJRT (Python was only used at build time).
//!
//! Wire schema: one JSON object per line; requests carry per-request
//! generation fields, responses are `delta`/`done`/`error` frames, and
//! `{"op":"stats"}` returns live counters. The complete protocol
//! reference is docs/PROTOCOL.md at the repository root.
//!
//! The server runs with the prefix-reuse KV cache on, so the repeated
//! "tell me about alice." prompt below is served from cache on its second
//! appearance (`"cached_tokens"` in its done frame, hit counters in the
//! final stats frame).
//!
//!     cargo run --release --example serve_and_query

use std::sync::atomic::Ordering;

use hydra_serve::server::{spawn_local_opts, Client};
use hydra_serve::util::cli::Args;
use hydra_serve::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let variant = args.str_or("variant", "hydra_pp");
    let batch = args.usize_or("batch", 4);
    let cache_mb = args.usize_or("cache-mb", 64);

    let (port, shutdown, handle) =
        spawn_local_opts(hydra_serve::artifacts_dir(), size, variant, batch, cache_mb)?;
    println!("server starting on 127.0.0.1:{port} (compiling executables)…");
    let addr = format!("127.0.0.1:{port}");

    // Mixed per-request modes, queried concurrently: the server batches
    // them into one engine, applying each sequence's own criterion.
    let requests = [
        ("greedy", Json::obj(vec![
            ("id", Json::num(0.0)),
            ("prompt", Json::str("tell me about alice.")),
            ("max_new", Json::num(48.0)),
        ])),
        ("greedy", Json::obj(vec![
            ("id", Json::num(1.0)),
            ("prompt", Json::str("compute 17 + 25.")),
            ("max_new", Json::num(48.0)),
        ])),
        ("typical", Json::obj(vec![
            ("id", Json::num(2.0)),
            ("prompt", Json::str("who is frank?")),
            ("max_new", Json::num(48.0)),
            ("mode", Json::str("typical")),
            ("eps", Json::num(0.15)),
            ("temp", Json::num(0.7)),
            ("seed", Json::num(7.0)),
        ])),
        ("typical", Json::obj(vec![
            ("id", Json::num(3.0)),
            ("prompt", Json::str("describe a day for judy in tokyo.")),
            ("max_new", Json::num(48.0)),
            ("mode", Json::str("typical")),
            ("eps", Json::num(0.25)),
            ("temp", Json::num(0.7)),
            ("seed", Json::num(8.0)),
        ])),
    ];
    let mut joins = Vec::new();
    for (label, body) in requests {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> anyhow::Result<(String, String)> {
            let mut c = Client::connect(&addr)?;
            let resp = c.request(&body)?;
            Ok((label.to_string(), resp.to_string()))
        }));
    }
    for j in joins {
        let (label, resp) = j.join().expect("client thread")?;
        println!("\n[{label}] <- {resp}");
    }

    // Streaming session: deltas arrive as the engine commits tokens.
    println!("\nstreaming \"tell me about alice.\" …");
    let mut c = Client::connect(&addr)?;
    let fin = c.generate_stream("tell me about alice.", 48, |delta| {
        print!("{delta}");
        use std::io::Write;
        let _ = std::io::stdout().flush();
    })?;
    println!("\nfinal frame: {fin}");

    // The streamed prompt repeated an earlier one — served from the
    // prefix cache this time. Ask the server for its counters.
    let stats = c.stats()?;
    println!("\nserver stats: {stats}");

    shutdown.store(true, Ordering::Relaxed);
    let _ = handle.join();
    println!("\nserver stopped.");
    Ok(())
}
