//! Shared-prefix serving with the prefix-reuse KV cache — a walkthrough.
//!
//! Real fleets are dominated by shared prefixes: one system prompt fans
//! out to every user, and each conversation's history is a prefix of its
//! next turn. This example serves exactly that shape — N personas × M
//! user turns over a common preamble (`workload::shared_prefix`) — twice
//! on one engine:
//!
//!   pass 1 (cold): every admission pays a `prefill_*` call; completed
//!                  prefixes are published into the radix-tree cache.
//!   pass 2 (warm): admissions hit the cache — full-prompt hits restore
//!                  KV rows by copy and skip prefill entirely, partial
//!                  hits restore the shared prefix and chain-extend the
//!                  unseen tail.
//!
//! Under greedy acceptance the warm outputs are token-for-token identical
//! to the cold ones (asserted below) — the cache changes cost, never
//! content.
//!
//!     cargo run --release --example shared_prefix_serving
//!         [-- --personas 6 --turns 3 --max-new 24 --cache-mb 64]

use std::collections::HashMap;

use hydra_serve::draft;
use hydra_serve::engine::{Engine, EngineConfig};
use hydra_serve::runtime::Runtime;
use hydra_serve::scheduler::Scheduler;
use hydra_serve::tokenizer::Tokenizer;
use hydra_serve::util::cli::Args;
use hydra_serve::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let personas = args.usize_or("personas", 6);
    let turns = args.usize_or("turns", 3);
    let max_new = args.usize_or("max-new", 24);
    let cache_mb = args.usize_or("cache-mb", 64);

    let rt = Runtime::new(hydra_serve::artifacts_dir())?;
    let tok = Tokenizer::load(&rt.manifest.dir.join("tokenizer.json"))?;
    let variant = ["hydra_pp", "hydra", "medusa"]
        .into_iter()
        .find(|v| draft::available(&rt.manifest, &size, v))
        .unwrap_or("ar")
        .to_string();
    let batch = rt.manifest.batch_buckets[&size].iter().copied().max().unwrap_or(1);
    let tree = if variant == "ar" {
        hydra_serve::tree::TreeTopology::ar()
    } else {
        draft::tuned_tree(&rt.manifest, &size, &variant, batch)?
    };

    // One engine for both passes: the prefix cache carries across.
    let mut engine = Engine::new(
        &rt,
        EngineConfig { size: size.clone(), variant: variant.clone(), tree, batch, seed: 7 },
    )?;
    engine.enable_prefix_cache(cache_mb << 20);
    println!("engine: {size}/{variant} b{batch}, prefix cache {cache_mb} MiB");

    let params = workload::default_params(&tok, max_new);
    let limit = rt.manifest.seq_max / 2;
    // (prompt tokens) -> generated ids from the cold pass, keyed by the
    // request's position in the workload (ids differ between passes).
    let mut cold_outputs: HashMap<usize, Vec<u32>> = HashMap::new();

    for (pass_idx, pass) in ["cold", "warm"].iter().enumerate() {
        let reqs: Vec<_> =
            workload::shared_prefix(&tok, &params, personas, turns, (pass_idx * 10_000) as u64)
                .into_iter()
                .filter(|r| r.prompt_ids.len() <= limit)
                .collect();
        let id_base = (pass_idx * 10_000) as u64;
        let n = reqs.len();
        let prefills0 = engine.phase.prefill_calls;
        let stats0 = engine.prefix_cache_stats().unwrap();

        let mut sched = Scheduler::default();
        sched.submit_all(reqs);
        let t0 = std::time::Instant::now();
        let outputs = sched.run_all(&mut engine)?;
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(outputs.len(), n);

        let stats = engine.prefix_cache_stats().unwrap();
        let tokens: usize = outputs.iter().map(|o| o.generated.len()).sum();
        println!(
            "\n[{pass}] {n} requests, {tokens} tokens in {dt:.2}s ({:.1} tok/s)\n\
             [{pass}] prefill calls: {}, full hits: {}, partial hits: {}, \
             prompt tokens reused: {}",
            tokens as f64 / dt,
            engine.phase.prefill_calls - prefills0,
            stats.full_hits - stats0.full_hits,
            stats.partial_hits - stats0.partial_hits,
            stats.tokens_reused - stats0.tokens_reused,
        );

        // Greedy determinism check: warm output == cold output, per request.
        for o in &outputs {
            let key = (o.req_id - id_base) as usize;
            if pass_idx == 0 {
                cold_outputs.insert(key, o.generated.clone());
            } else {
                assert_eq!(
                    Some(&o.generated),
                    cold_outputs.get(&key),
                    "warm greedy output must be identical to cold (request {key})"
                );
            }
        }
        if pass_idx == 1 {
            println!("[warm] all outputs byte-identical to the cold pass ✓");
            println!(
                "[warm] cache: {} nodes, {:.2} MiB of {} MiB",
                stats.nodes,
                stats.bytes_in_use as f64 / (1 << 20) as f64,
                stats.byte_budget >> 20,
            );
        }
    }

    Ok(())
}
