//! END-TO-END DRIVER (DESIGN.md §E2E): serve a realistic batched workload
//! through the full stack — tokenizer → continuous-batching scheduler →
//! speculative engine → PJRT verify/commit artifacts — and report
//! latency/throughput, comparing Hydra++ speculative decoding against the
//! autoregressive baseline on the same prompts.
//!
//!     cargo run --release --example batched_throughput [-- --batch 4 --requests 12]

use hydra_serve::bench::Table;
use hydra_serve::draft;
use hydra_serve::engine::{Engine, EngineConfig};
use hydra_serve::metrics::RunMetrics;
use hydra_serve::runtime::Runtime;
use hydra_serve::scheduler::Scheduler;
use hydra_serve::tokenizer::Tokenizer;
use hydra_serve::util::cli::Args;
use hydra_serve::util::stats::summarize;
use hydra_serve::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let batch = args.usize_or("batch", 4);
    let n_requests = args.usize_or("requests", 12);
    let max_new = args.usize_or("max-new", 64);

    let rt = Runtime::new(hydra_serve::artifacts_dir())?;
    let tok = Tokenizer::load(&rt.manifest.dir.join("tokenizer.json"))?;
    let prompts = workload::load_prompts(&rt.manifest.dir)?;
    let chat = workload::mt_bench(&prompts);

    let mut table = Table::new(
        &format!("Batched serving: {n_requests} requests, batch {batch}, {max_new} new tokens"),
        &["strategy", "tok/s", "seq latency p50 ms", "p99 ms", "accept len", "steps"],
    );
    for variant in ["ar", "hydra_pp"] {
        if variant != "ar" && !draft::available(&rt.manifest, &size, variant) {
            continue;
        }
        let tree = draft::tuned_tree(&rt.manifest, &size, variant, batch)?;
        let mut engine = Engine::new(
            &rt,
            EngineConfig {
                size: size.clone(),
                variant: variant.to_string(),
                tree,
                batch,
                seed: 9,
            },
        )?;
        // Warmup (compiles this config's executables). Requests default to
        // greedy acceptance via their per-request SamplingParams.
        let w = workload::to_requests(&chat[..1], &tok, &workload::default_params(&tok, 4), 999);
        engine.admit(w)?;
        engine.run_to_completion()?;
        engine.take_outputs();

        let mut sched = Scheduler::default();
        sched.submit_all(workload::to_requests(
            &chat[..n_requests.min(chat.len())],
            &tok,
            &workload::default_params(&tok, max_new),
            0,
        ));
        let mut m = RunMetrics::new(variant);
        let t0 = std::time::Instant::now();
        let outputs = sched.run_all(&mut engine)?;
        m.decode_wall = t0.elapsed();
        for o in &outputs {
            m.tokens_generated += o.generated.len();
            for &a in &o.accept_hist {
                m.accept.record(a);
            }
            m.seq_latency_ms.extend(o.total_ms);
            m.steps += o.steps;
        }
        let lat = summarize(&m.seq_latency_ms);
        table.row(vec![
            draft::label(variant).to_string(),
            format!("{:.1}", m.throughput()),
            format!("{:.0}", lat.p50),
            format!("{:.0}", lat.p99),
            format!("{:.2}", m.mean_accept_len()),
            format!("{}", m.steps),
        ]);
        // Show one real exchange so the output is demonstrably sensible.
        if variant == "hydra_pp" {
            if let Some(o) = outputs.first() {
                let mut text = tok.decode(&o.generated);
                if let Some(p) = text.find("<end>") {
                    text.truncate(p);
                }
                println!("\nsample> {}\nanswer> {}", chat[0].prompt, text.trim());
            }
        }
    }
    table.print();
    Ok(())
}
