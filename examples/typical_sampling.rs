//! Typical-acceptance sampling demo (§6.3): generate with the non-greedy,
//! non-distribution-preserving typical criterion at several posterior
//! thresholds ε, and show that Hydra++ keeps long acceptances while the
//! output remains base-typical (quality proxy: mean log p_base).
//!
//!     cargo run --release --example typical_sampling [-- --eps 0.15]

use hydra_serve::draft;
use hydra_serve::engine::{AcceptMode, Engine, EngineConfig, Request};
use hydra_serve::runtime::Runtime;
use hydra_serve::tokenizer::{format_prompt, Tokenizer, STOP_TEXT};
use hydra_serve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let variant = args.str_or("variant", "hydra_pp");
    let prompt = args.str_or("prompt", "describe a day for erin in paris.");
    let max_new = args.usize_or("max-new", 56);

    let rt = Runtime::new(hydra_serve::artifacts_dir())?;
    let tok = Tokenizer::load(&rt.manifest.dir.join("tokenizer.json"))?;
    let tree = draft::tuned_tree(&rt.manifest, &size, &variant, 1)?;

    println!("prompt: {prompt}\n");
    for (label, mode) in [
        ("greedy".to_string(), AcceptMode::Greedy),
        ("typical ε=0.05".to_string(),
         AcceptMode::Typical { eps: 0.05, alpha: 0.05f32.sqrt(), temp: 0.7 }),
        (format!("typical ε={}", args.f64_or("eps", 0.15)),
         AcceptMode::Typical {
             eps: args.f64_or("eps", 0.15) as f32,
             alpha: (args.f64_or("eps", 0.15) as f32).sqrt(),
             temp: 0.7,
         }),
        ("typical ε=0.25".to_string(),
         AcceptMode::Typical { eps: 0.25, alpha: 0.25f32.sqrt(), temp: 0.7 }),
    ] {
        let mut engine = Engine::new(
            &rt,
            EngineConfig {
                size: size.clone(),
                variant: variant.clone(),
                tree: tree.clone(),
                batch: 1,
                mode,
                seed: 2024,
            },
        )?;
        engine.admit(vec![Request {
            id: 0,
            prompt_ids: tok.encode(&format_prompt(&prompt)),
            max_new,
            stop_ids: tok.encode(STOP_TEXT),
        }])?;
        engine.run_to_completion()?;
        let out = engine.take_outputs().pop().unwrap();
        let mut text = tok.decode(&out.generated);
        if let Some(p) = text.find(STOP_TEXT) {
            text.truncate(p);
        }
        println!(
            "[{label:<16}] accept={:.2} logp={:+.3} | {}",
            out.mean_accept_len,
            out.mean_logprob,
            text.trim()
        );
    }
    Ok(())
}
