//! Typical-acceptance sampling demo (§6.3): generate with the non-greedy,
//! non-distribution-preserving typical criterion at several posterior
//! thresholds ε, and show that Hydra++ keeps long acceptances while the
//! output remains base-typical (quality proxy: mean log p_base).
//!
//! Since the acceptance criterion is a per-request `SamplingParams`, ONE
//! engine serves all four configurations — the requests simply carry
//! different modes (and per-request seeds) through the scheduler.
//!
//!     cargo run --release --example typical_sampling [-- --eps 0.15]

use hydra_serve::draft;
use hydra_serve::engine::{AcceptMode, Engine, EngineConfig, Request, SamplingParams};
use hydra_serve::runtime::Runtime;
use hydra_serve::scheduler::Scheduler;
use hydra_serve::tokenizer::{format_prompt, Tokenizer, STOP_TEXT};
use hydra_serve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let size = args.str_or("size", "s");
    let variant = args.str_or("variant", "hydra_pp");
    let prompt = args.str_or("prompt", "describe a day for erin in paris.");
    let max_new = args.usize_or("max-new", 56);

    let rt = Runtime::new(hydra_serve::artifacts_dir())?;
    let tok = Tokenizer::load(&rt.manifest.dir.join("tokenizer.json"))?;
    let tree = draft::tuned_tree(&rt.manifest, &size, &variant, 1)?;

    println!("prompt: {prompt}\n");
    let eps_flag = args.f64_or("eps", 0.15) as f32;
    let configs: Vec<(String, AcceptMode)> = vec![
        ("greedy".to_string(), AcceptMode::Greedy),
        ("typical ε=0.05".to_string(),
         AcceptMode::Typical { eps: 0.05, alpha: 0.05f32.sqrt(), temp: 0.7 }),
        (format!("typical ε={eps_flag}"),
         AcceptMode::Typical { eps: eps_flag, alpha: eps_flag.sqrt(), temp: 0.7 }),
        ("typical ε=0.25".to_string(),
         AcceptMode::Typical { eps: 0.25, alpha: 0.25f32.sqrt(), temp: 0.7 }),
    ];

    // One engine, one scheduler — each request carries its own criterion.
    let mut engine = Engine::new(
        &rt,
        EngineConfig {
            size: size.clone(),
            variant: variant.clone(),
            tree,
            batch: 1,
            seed: 2024,
        },
    )?;
    let mut sched = Scheduler::default();
    for (i, (_, mode)) in configs.iter().enumerate() {
        sched.submit(Request::new(
            i as u64,
            tok.encode(&format_prompt(&prompt)),
            SamplingParams {
                mode: *mode,
                max_new,
                stop_ids: tok.encode(STOP_TEXT),
                top_k: args.usize_or("top-k", 0),
                seed: Some(2024 + i as u64),
                ..SamplingParams::default()
            },
        ));
    }
    let outputs = sched.run_all(&mut engine)?;

    for (i, (label, _)) in configs.iter().enumerate() {
        let out = outputs.iter().find(|o| o.req_id == i as u64).expect("output");
        let mut text = tok.decode(&out.generated);
        if let Some(p) = text.find(STOP_TEXT) {
            text.truncate(p);
        }
        println!(
            "[{label:<16}] accept={:.2} logp={:+.3} | {}",
            out.mean_accept_len,
            out.mean_logprob,
            text.trim()
        );
    }
    Ok(())
}
